"""MemoryGovernor — capacity-aware admission control + preemption.

Sits between :class:`~repro.serving.scheduler.Scheduler` and
:class:`~repro.serving.kv_cache.PagedKVCache`:

  * **admission** — a queued sequence is admitted only when the
    :class:`~repro.serving.admission.ledger.CapacityLedger` can commit its
    whole attention window (prompt + ``max_new_tokens`` in blocks).  With
    the default ``overcommit_ratio = 1`` this closes the
    ``demand_pager_gave_up`` hole as a hard invariant: every set of running
    windows has a resident placement, so the pager's fixpoint scan always
    converges.  The *policy* (FCFS / recycle-affinity / priority classes)
    decides the order — recycle-affinity is the FPR-aware one: it hands
    freed blocks to the same stream's next request so recycling stays hot
    and the context-exit fence is averted.

  * **preemption** — under pressure (optimistic over-commit, or a blocked
    higher-priority request) the governor picks a victim (lowest priority
    class, then most recently admitted — vLLM's LIFO choice, which
    minimises wasted work) and the engine applies one of two strategies:

      - ``recompute`` — free the victim's mapping (the blocks recycle,
        fence-free under FPR) and re-prefill from scratch on re-admission;
      - ``swap`` — push the victim's resident blocks out through the
        watermark evictor's swap path (one merged fence, contents
        round-trip through the swap store) and keep mapping + generated
        tokens; re-admission demand-faults the blocks back in.

    Both strategies preserve decoded tokens exactly (greedy decode is
    deterministic; swap round-trips block contents bit-for-bit).

The governor is engine-agnostic bookkeeping: it never touches the cache or
scheduler itself — the engine drives both and reports back, which keeps
the policy layer (this module) cleanly separated from the mechanism layer
(core/), the split eBPF-mm argues for.
"""

from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass
from typing import Optional

from repro.core.events import (AdmissionDecision, EventBus,
                               PreemptionResolved)
from repro.serving.admission.ledger import CapacityError, CapacityLedger
from repro.serving.admission.policies import (AdmissionPolicy, PriorityPolicy,
                                              make_policy)
from repro.serving.admission.quota import TenantQuota

PREEMPT_STRATEGIES = ("recompute", "swap")


@dataclass
class GovernorConfig:
    """Knobs for the admission/preemption subsystem.

    ``tenant_caps`` enables per-tenant quota enforcement (tenant = request
    ``stream``): a dict of tenant → max committed window blocks, with
    ``tenant_default_cap`` applying to unlisted tenants (``None`` =
    unlimited).  A tenant at its cap is skipped by admission until a
    release credits it back — see
    :class:`~repro.serving.admission.quota.TenantQuota`.
    """

    policy: "str | AdmissionPolicy" = "fcfs"
    preempt: str = "recompute"          # recompute | swap
    overcommit_ratio: float = 1.0       # 1.0 = hard capacity invariant
    affinity_window: int = 8            # freed streams remembered (newest first)
    tenant_caps: "dict | None" = None   # tenant → committed-block cap
    tenant_default_cap: "int | None" = None

    def __post_init__(self) -> None:
        if self.preempt not in PREEMPT_STRATEGIES:
            raise ValueError(f"unknown preempt strategy {self.preempt!r}; "
                             f"known: {PREEMPT_STRATEGIES}")


@dataclass
class GovernorStats:
    admitted: int = 0
    rejected_overcommit: int = 0        # admission rounds refused for capacity
    holds: int = 0                      # rounds a policy held free capacity
                                        # for a starved request (deadline SLA)
    preemptions_recompute: int = 0
    preemptions_swap: int = 0
    affinity_hits: int = 0              # admission matched a freed stream
    affinity_misses: int = 0            # a freed stream was known, no match
    chunk_grows: int = 0                # reservation growths past admission
                                        # (chunked prefill / COW divergence)

    @property
    def affinity_hit_rate(self) -> Optional[float]:
        n = self.affinity_hits + self.affinity_misses
        return round(self.affinity_hits / n, 4) if n else None

    def snapshot(self) -> dict:
        d = dict(self.__dict__)
        d["affinity_hit_rate"] = self.affinity_hit_rate
        return d


class MemoryGovernor:
    """Capacity ledger + admission policy + victim selection."""

    def __init__(self, capacity_blocks: int, block_size: int, *,
                 num_workers: int = 1,
                 config: GovernorConfig | None = None,
                 bus: EventBus | None = None):
        self.config = config or GovernorConfig()
        self.block_size = block_size
        self.bus = bus if bus is not None else EventBus()
        self.ledger = CapacityLedger(
            capacity_blocks, num_workers=num_workers,
            overcommit_ratio=self.config.overcommit_ratio)
        self.policy = make_policy(self.config.policy)
        # per-tenant quota rides the AdmissionDecision stream for charging
        # and the capacity predicate for refusal (see quota.py); a bare
        # tenant_default_cap (uniform cap, no per-tenant overrides) is a
        # valid configuration and must enable enforcement too
        self.quota = (TenantQuota(self.config.tenant_caps or {},
                                  default_cap=self.config.tenant_default_cap,
                                  bus=self.bus)
                      if (self.config.tenant_caps is not None
                          or self.config.tenant_default_cap is not None)
                      else None)
        self.stats = GovernorStats()
        # SLA-aware policies consume the governor's own decision stream
        if hasattr(self.policy, "attach"):
            self.policy.attach(self.bus)
        # hold-capable policies need the starvation predicate so a
        # quota-blocked request never engages (or sustains) a hold
        if getattr(self.policy, "can_hold", False):
            self.policy.starvation_fits = self._starvable_fits
        # preemption bookkeeping is event-driven: the engine publishes
        # PreemptionResolved; virtual-time sims may call count_preempt
        # directly instead (they have no engine loop)
        self.bus.subscribe(PreemptionResolved,
                           lambda evt: self.count_preempt(evt.strategy))
        self._freed_streams: deque[str] = deque(
            maxlen=max(1, self.config.affinity_window))
        self._admit_seq = itertools.count(1)
        self._admit_order: dict[int, int] = {}      # rid → admission ordinal
        # Prefix-sharing hooks (both engine-installed, both optional):
        # ``probe_shared(r)`` returns how many leading window blocks the
        # request would attach to the live sharing index instead of
        # allocating, so admission reserves only the estimated *unique*
        # remainder (quota charging follows the same estimate).
        # ``shared_residual()`` returns the indexed live blocks covered by
        # NO running reservation (orphaned prefixes whose owner completed
        # or diverged); fits() charges them against capacity so every
        # physical block is accounted either by a reservation or by the
        # residual — the pager-fixpoint guarantee survives sharing.
        self.probe_shared = None
        self.shared_residual = None
        # Chunked-prefill admission (engine-installed): when set to the
        # chunk size in blocks, a fresh request is admitted when its first
        # chunk plus one active tail block fits — the reservation then
        # grows chunk-by-chunk through on_extend.  ``None`` keeps the
        # monolithic full-window reservation.
        self.chunk_blocks: "int | None" = None
        # Hierarchical island topology (engine-installed via reshard /
        # construction): the worker → island partition, used only to
        # aggregate per-worker ledger commitments per island in
        # counters(); None (flat) keeps counter key sets unchanged.
        self.topology = None
        # Observability hook (engine-installed): called with the queue
        # depth of every non-empty admission round — feeds the
        # ``admission.obs.queue_depth`` histogram directly, without the
        # AdmissionDecision event's blocked_rid scan (the hook stays cheap
        # even when a tracer forces bus.wants(AdmissionDecision) on).
        self.observe_queue_depth = None

    # ------------------------------------------------------------- windows
    def window_blocks(self, r) -> int:
        """Blocks to reserve for ``r``: the full attention window (prompt
        + budget), minus — when the prefix-sharing probe is installed —
        the leading blocks the request would *attach* rather than
        allocate.  At least one block is always reserved (the active
        decode tail is private even under a fully shared prompt)."""
        need = len(r.prompt) + r.max_new_tokens
        full = max(1, -(-need // self.block_size))
        if self.probe_shared is not None:
            shared = min(int(self.probe_shared(r)), full - 1)
            return max(1, full - shared)
        return full

    def admit_blocks(self, r) -> int:
        """Blocks the *admission* reserves for ``r``.

        Monolithic (``chunk_blocks is None``): the full shared-adjusted
        window.  Chunked: the first prefill chunk plus one active tail
        block — the rest is grown per chunk through :meth:`on_extend`.  A
        swap-preempted re-admission carries a surviving mapping; its
        reservation must cover the blocks that mapping actually holds
        (they fault back in full), never a fresh chunk estimate.
        """
        m = getattr(r, "mapping", None)
        if m is not None:
            return max(1, m.num_blocks - getattr(m, "prefix_hits", 0))
        full = self.window_blocks(r)
        if self.chunk_blocks is None:
            return full
        return min(full, self.chunk_blocks + 1)

    def admissible_ever(self, r) -> bool:
        """Can this request's window ever fit (even on an empty pool)?

        Deliberately the *full* shared-adjusted window even under chunked
        admission: chunks are individually small, but the full window
        still bounds the request's final residency — a window that can
        never fit would only ever grow into a guaranteed CapacityError.
        """
        return self.window_blocks(r) <= self.ledger.limit

    def fits(self, r) -> bool:
        """The admission capacity predicate: the ledger can commit the
        admission reservation (plus any unreserved shared-prefix residual)
        AND the tenant (when quotas are on) is under its cap.  Quota is
        charged on the full window estimate even under chunked admission —
        tenant caps bound final residency, not first-chunk footprints."""
        blocks = self.admit_blocks(r)
        residual = (int(self.shared_residual())
                    if self.shared_residual is not None else 0)
        if not self.ledger.fits(blocks + residual):
            return False
        return (self.quota is None
                or self.quota.allows(r.stream, self.window_blocks(r)))

    def _starvable_fits(self, r) -> bool:
        """``fits`` for starvation accounting (preemption beneficiaries,
        ``blocked_rid`` aging): a *quota*-blocked request reads as
        fitting, because freeing capacity — by preempting other tenants
        or holding admissions — can never credit its tenant's cap.  Only
        capacity-blocked requests may drive preemption or deadline
        holds."""
        if (self.quota is not None
                and not self.quota.allows(r.stream, self.window_blocks(r))):
            return True
        return self.fits(r)

    def reshard(self, new_num_workers: int, translation,
                topology=None) -> None:
        """Elastic topology change: remap the ledger's per-worker shares
        (quota caps are per-tenant, not per-worker — untouched).
        ``topology`` optionally installs the new worker → island partition
        so the counters can aggregate commitments per island; omitting it
        across a count change drops the partition to flat."""
        self.ledger.reshard(new_num_workers, translation)
        if topology is not None:
            self.topology = None if topology.is_flat else topology
        elif (self.topology is not None
              and self.topology.num_workers != new_num_workers):
            self.topology = None

    # ----------------------------------------------------------- admission
    def select(self, queue: list) -> Optional[int]:
        """Index of the next queue entry to admit, or None.

        Every round publishes one :class:`AdmissionDecision` event —
        ``"admit"`` with the chosen rid, or ``"reject"`` when a non-empty
        queue seats nothing.  A refusal counts one ``rejected_overcommit``
        (capacity) and additionally one ``holds`` when the policy declined
        requests that *do* fit (the deadline policy draining capacity to a
        starved window).  ``blocked_rid`` names the policy's most urgent
        non-fitting request; SLA-aware policies consume it to age starved
        requests (see :class:`~repro.serving.admission.policies.
        DeadlinePolicy`).
        """
        if not queue:
            return None
        if self.observe_queue_depth is not None:
            self.observe_queue_depth(len(queue))
        fits = self.fits
        idx = self.policy.select(queue, fits, tuple(self._freed_streams))
        if idx is None:
            # a hold (hold-capable policy refusing while something still
            # fits — capacity deliberately drained for a starved window)
            # is NOT a capacity refusal; keep the counters disjoint so
            # rejected_overcommit retains its documented meaning.  A round
            # where the only refusals are tenant caps (the window fits the
            # ledger) is a quota rejection, not an over-commit.
            if (getattr(self.policy, "can_hold", False)
                    and any(fits(r) for r in queue)):
                self.stats.holds += 1
            elif self.quota is not None and any(
                    self.ledger.fits(self.admit_blocks(r))
                    and not self.quota.allows(r.stream,
                                              self.window_blocks(r))
                    for r in queue):
                self.quota.note_rejection()
            else:
                self.stats.rejected_overcommit += 1
            self._publish_decision("reject", None, queue, fits)
            return None
        # Affinity accounting: a hit means the admission exploited the
        # best *achievable* recycling affinity — the freshest freed stream
        # with any queued request.  (Matching nothing achievable counts
        # neither way; FCFS only hits when arrival order happens to align.)
        achievable = next(
            (s for s in self._freed_streams
             if any(q.stream == s for q in queue)), None)
        if achievable is not None:
            if queue[idx].stream == achievable:
                self.stats.affinity_hits += 1
            else:
                self.stats.affinity_misses += 1
        self._publish_decision("admit", queue[idx], queue, fits)
        return idx

    def _publish_decision(self, decision: str, request,
                          queue: list, fits) -> None:
        if not self.bus.wants(AdmissionDecision):
            return
        # blocked_rid is only computed when someone is listening — the
        # full-queue fits() scan stays off the unobserved hot path
        self.bus.publish(AdmissionDecision(
            decision=decision,
            rid=None if request is None else request.rid,
            policy=self.policy.name,
            queue_depth=len(queue),
            window_blocks=(None if request is None
                           else self.window_blocks(request)),
            blocked_rid=self.policy.most_urgent_blocked(
                queue, self._starvable_fits),
            tenant=None if request is None else request.stream))

    def on_admit(self, r, worker: int = 0) -> None:
        """Commit the admitted request's reservation (raises on
        over-commit) — the full window monolithically, the first chunk
        plus tail under chunked admission (see :meth:`admit_blocks`)."""
        self.ledger.reserve(r.rid, self.admit_blocks(r), worker)
        self._admit_order[r.rid] = next(self._admit_seq)
        self.stats.admitted += 1

    def on_allocated(self, r, unique_blocks: int) -> None:
        """Reconcile ``r``'s reservation with the allocation that actually
        happened: admission reserved a probe-based *estimate* of the
        unique footprint; the mapping now knows the truth
        (``num_blocks - prefix_hits``).  Growth is refused loudly
        (:class:`CapacityError`) like any reservation — the engine frees
        the mapping and retries under pressure relief."""
        if not self.ledger.holds(r.rid):
            return
        unique = max(1, int(unique_blocks))
        held = self.ledger.entries[r.rid].blocks
        if unique > held:
            self.ledger.grow(r.rid, unique - held)
        elif unique < held:
            self.ledger.shrink(r.rid, held - unique)

    def on_extend(self, r, n_blocks: int) -> None:
        """A running sequence grew its mapping beyond the admitted
        reservation (a prefill chunk, a COW divergence, a decode crossing
        a block boundary): grow the reservation or refuse loudly."""
        self.ledger.grow(r.rid, n_blocks)
        self.stats.chunk_grows += 1

    def defer_growth(self, r, n_blocks: int, queue: list) -> bool:
        """Should ``r``'s next chunk growth yield this step?

        Consults the policy's optional ``defer_growth(r, n, queue, fits)``
        hook — how a policy ranks a partially-prefilled grower against
        queued mice (or an imminent reshard; see
        :meth:`note_reshard_distance`).  Policies without the hook never
        defer.  Deferral is advisory and must be bounded by the policy —
        a grower always eventually proceeds.
        """
        hook = getattr(self.policy, "defer_growth", None)
        if hook is None:
            return False
        return bool(hook(r, n_blocks, queue, self.fits))

    def note_reshard_distance(self, steps: "int | None") -> None:
        """Expose the distance (engine/sim steps) to the next planned
        topology change; reshard-aware policies read it in ``select`` and
        ``defer_growth`` (``None`` = no reshard scheduled)."""
        self.policy.reshard_distance = steps

    def on_release(self, r) -> None:
        """Completion or preemption: return the window, remember the stream."""
        if self.ledger.holds(r.rid):
            self.ledger.release(r.rid)
        if self.quota is not None:
            self.quota.release(r.rid)
        self._admit_order.pop(r.rid, None)
        self.note_freed_stream(r.stream)

    def note_freed_stream(self, stream: str) -> None:
        """Newest-first affinity hint (dedup keeps the deque informative)."""
        if stream in self._freed_streams:
            self._freed_streams.remove(stream)
        self._freed_streams.appendleft(stream)

    # ---------------------------------------------------------- preemption
    def choose_victim(self, running: dict, *,
                      below_priority: int | None = None,
                      exclude: tuple = ()) -> Optional[object]:
        """Lowest priority class, then most recently admitted (vLLM LIFO).

        ``below_priority`` restricts victims to strictly lower classes
        (priority-pressure preemption must never evict an equal or higher
        class); ``exclude`` protects requests already being served this
        scan (e.g. the fault that triggered the pressure).
        """
        candidates = [
            r for r in running.values()
            if r.rid not in exclude
            and (below_priority is None
                 or getattr(r, "priority", 0) < below_priority)]
        if not candidates:
            return None
        return max(candidates,
                   key=lambda r: (-getattr(r, "priority", 0),
                                  self._admit_order.get(r.rid, 0)))

    def count_preempt(self, strategy: str) -> None:
        if strategy == "swap":
            self.stats.preemptions_swap += 1
        else:
            self.stats.preemptions_recompute += 1

    def wants_priority_preempt(self, queue: list) -> Optional[int]:
        """Index of a blocked queued request whose class justifies evicting
        a lower-class running sequence (priority policy only)."""
        if not isinstance(self.policy, PriorityPolicy) or not queue:
            return None
        return self.policy.best_blocked(queue, self._starvable_fits)

    # ------------------------------------------------------------ counters
    def counters(self) -> dict:
        d = self.stats.snapshot()
        d["policy"] = self.policy.name
        d["preempt_strategy"] = self.config.preempt
        d["ledger"] = self.ledger.counters()
        if self.topology is not None:
            t = self.topology
            per_worker = d["ledger"]["per_worker_committed"]
            d["ledger"]["per_island_committed"] = [
                sum(per_worker[w] for w in t.workers_in(i)
                    if w < len(per_worker))
                for i in range(t.num_islands)]
        d["quota"] = (self.quota.counters() if self.quota is not None
                      else {"enabled": False, "tenants": 0, "rejections": 0})
        return d


__all__ = ["CapacityError", "GovernorConfig", "GovernorStats",
           "MemoryGovernor", "PREEMPT_STRATEGIES"]
