"""Admission-control & preemption subsystem — the capacity-aware memory
governor between the scheduler and the paged KV cache.

See :mod:`repro.serving.admission.governor` for the design overview:
the ledger makes "committed windows ≤ pool" an admission-time invariant
(closing the demand-pager give-up hole), the policies decide which queued
request inherits freed blocks (recycle-affinity keeps FPR recycling hot),
and the preemption strategies (recompute / swap-through-the-evictor) keep
over-committed configurations sound instead of shipping ``-1`` rows.
"""

from repro.serving.admission.governor import (PREEMPT_STRATEGIES,
                                              GovernorConfig, GovernorStats,
                                              MemoryGovernor)
from repro.serving.admission.ledger import CapacityError, CapacityLedger
from repro.serving.admission.policies import (AdmissionPolicy,
                                              DeadlinePolicy, FcfsPolicy,
                                              PriorityPolicy,
                                              RecycleAffinityPolicy,
                                              make_policy)
from repro.serving.admission.quota import TenantQuota

__all__ = [
    "AdmissionPolicy",
    "CapacityError",
    "CapacityLedger",
    "DeadlinePolicy",
    "FcfsPolicy",
    "GovernorConfig",
    "GovernorStats",
    "MemoryGovernor",
    "PREEMPT_STRATEGIES",
    "PriorityPolicy",
    "RecycleAffinityPolicy",
    "TenantQuota",
    "make_policy",
]
