"""Capacity ledger — the admission-phase accounting behind the governor.

The paper moves the shootdown check from release time to **allocation
time** (§IV-A); the governor moves the *capacity* check one phase earlier
still, to **admission** time: a sequence is only admitted when the pool can
hold its whole attention window, so the demand pager's fixpoint scan in
``Engine.step`` always has a resident placement to converge to.  The
ledger is the bookkeeping for that invariant: committed window blocks per
pool (and per worker shard, for balance/diagnostics), with reservations
refused — not silently shrunk — when they would over-commit.

``overcommit_ratio > 1`` relaxes the invariant into vLLM-style optimism:
admissions may over-commit the pool by that factor, and the *preemption*
path (``MemoryGovernor`` victim strategies) restores soundness under
pressure instead of the admission refusal.  ``overcommit_ratio = 1`` (the
default) makes "committed ≤ capacity" a hard invariant and pager give-ups
impossible.
"""

from __future__ import annotations

from dataclasses import dataclass, field


class CapacityError(RuntimeError):
    """An admission/reservation would over-commit the block pool."""


@dataclass
class LedgerEntry:
    blocks: int
    worker: int


@dataclass
class CapacityLedger:
    """Committed attention-window blocks per pool / worker shard.

    ``capacity`` is the physical pool size; ``limit`` is what admissions
    may commit against (``capacity × overcommit_ratio``).  Every admitted
    sequence holds one reservation for its full window (prompt +
    ``max_new_tokens``, in blocks) from admission until completion or
    preemption — the conservative bound that guarantees the demand pager a
    fixpoint whenever ``committed ≤ capacity``.
    """

    capacity: int
    num_workers: int = 1
    overcommit_ratio: float = 1.0
    committed: int = 0
    peak_committed: int = 0
    per_worker: list[int] = field(default_factory=list)
    entries: dict[int, LedgerEntry] = field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.capacity <= 0:
            raise ValueError(f"capacity must be positive, got {self.capacity}")
        if self.overcommit_ratio < 1.0:
            raise ValueError("overcommit_ratio must be >= 1.0 "
                             f"(got {self.overcommit_ratio})")
        if not self.per_worker:
            self.per_worker = [0] * max(1, self.num_workers)

    @property
    def limit(self) -> int:
        return max(1, int(self.capacity * self.overcommit_ratio))

    @property
    def available(self) -> int:
        return self.limit - self.committed

    def fits(self, blocks: int) -> bool:
        return self.committed + blocks <= self.limit

    def reserve(self, rid: int, blocks: int, worker: int = 0) -> None:
        """Commit ``blocks`` for request ``rid``; raises on over-commit."""
        if rid in self.entries:
            raise ValueError(f"request {rid} already holds a reservation")
        if blocks <= 0:
            raise ValueError(f"reservation must be positive, got {blocks}")
        if not self.fits(blocks):
            raise CapacityError(
                f"admitting {blocks} blocks would commit "
                f"{self.committed + blocks} > limit {self.limit} "
                f"(pool {self.capacity})")
        w = worker % len(self.per_worker)
        self.entries[rid] = LedgerEntry(blocks, w)
        self.committed += blocks
        self.per_worker[w] += blocks
        self.peak_committed = max(self.peak_committed, self.committed)

    def grow(self, rid: int, extra_blocks: int) -> None:
        """Enlarge an existing reservation (chunked-prefill / decode-path
        ``extend``): the growth is refused — not silently clipped — when it
        would over-commit the limit, mirroring :meth:`reserve`."""
        if extra_blocks <= 0:
            raise ValueError(f"growth must be positive, got {extra_blocks}")
        e = self.entries[rid]
        if not self.fits(extra_blocks):
            raise CapacityError(
                f"growing {rid} by {extra_blocks} blocks would commit "
                f"{self.committed + extra_blocks} > limit {self.limit} "
                f"(pool {self.capacity})")
        e.blocks += extra_blocks
        self.committed += extra_blocks
        self.per_worker[e.worker] += extra_blocks
        self.peak_committed = max(self.peak_committed, self.committed)

    def shrink(self, rid: int, blocks: int) -> None:
        """Return part of a reservation without releasing it — the
        prefix-sharing reconcile path: a sequence admitted on a
        unique-block *estimate* turned out to attach more shared blocks
        than probed, so its actual unique footprint is smaller.  The
        reservation must stay positive (a running sequence always owns at
        least one private block — its active tail)."""
        if blocks <= 0:
            raise ValueError(f"shrink must be positive, got {blocks}")
        e = self.entries[rid]
        if blocks >= e.blocks:
            raise ValueError(
                f"shrinking {rid} by {blocks} would empty its reservation "
                f"of {e.blocks} blocks; release() it instead")
        e.blocks -= blocks
        self.committed -= blocks
        self.per_worker[e.worker] -= blocks

    def release(self, rid: int) -> int:
        """Return ``rid``'s reservation to the pool (completion/preemption)."""
        e = self.entries.pop(rid)
        self.committed -= e.blocks
        self.per_worker[e.worker] -= e.blocks
        return e.blocks

    def holds(self, rid: int) -> bool:
        return rid in self.entries

    def reshard(self, new_num_workers: int, translation) -> None:
        """Elastic topology change: remap per-worker commitments.

        Every live reservation's worker shard is rewritten through the
        old→new ``translation`` and the per-worker totals are rebuilt —
        total ``committed`` is untouched (capacity is a pool property, not
        a topology one), so the admission invariant survives the reshard
        unchanged.
        """
        if new_num_workers < 1:
            raise ValueError(f"need >= 1 worker, got {new_num_workers}")
        old_n = len(self.per_worker)
        per_worker = [0] * new_num_workers
        for e in self.entries.values():
            e.worker = (int(translation[e.worker]) % new_num_workers
                        if e.worker < old_n else e.worker % new_num_workers)
            per_worker[e.worker] += e.blocks
        self.per_worker = per_worker
        self.num_workers = new_num_workers

    def check(self) -> None:
        """Soundness invariant: the ledger never over-commits nor drifts."""
        total = sum(e.blocks for e in self.entries.values())
        assert total == self.committed, \
            f"ledger drift: entries sum {total} != committed {self.committed}"
        assert self.committed <= self.limit, \
            f"over-commit: {self.committed} > limit {self.limit}"
        assert all(v >= 0 for v in self.per_worker), \
            f"negative per-worker commit: {self.per_worker}"

    def counters(self) -> dict:
        return {
            "capacity": self.capacity,
            "limit": self.limit,
            "committed": self.committed,
            "peak_committed": self.peak_committed,
            "per_worker_committed": list(self.per_worker),
        }
