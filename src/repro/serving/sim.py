"""Worker-level simulation of fence impact — the paper's microbenchmark
harness (§V-A cases 1–5, §V-B eviction) at datacenter scale.

The paper measures how TLB shootdowns from I/O threads steal time from
compute threads.  The serving analogue: **alloc/free workers** (request
streams cycling KV blocks through mmap→access→munmap) steal time from
**compute workers** (decode/train steps) because a coherence fence drains
*every* worker's in-flight dispatch and stalls them for the fence cost.

Time is virtual (deterministic): each worker advances a clock; a fence at
time t adds ``fence_cost`` of stall to every worker whose clock overlaps
[t, t+fence_cost] — mirroring Fig. 3's lazy-shootdown asymmetry via the
``in_kernel_frac`` parameter (stalls while "in the kernel" are absorbed).

This is also the 1000+-node projection vehicle: the fence cost model
scales with replica count (log-tree table rebroadcast) and dispatch depth.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.config import FprConfig
from repro.core.contexts import ContextScope, derive_context
from repro.core.eviction import WatermarkEvictor, Watermarks
from repro.core.fpr import FprMemoryManager
from repro.core.shootdown import FenceCostModel, FenceEngine
from repro.serving.admission import (CapacityError, GovernorConfig,
                                     MemoryGovernor)


@dataclass
class SimConfig:
    num_blocks: int = 4096
    io_workers: int = 1               # mmap-access-munmap cyclers
    compute_workers: int = 0          # pure compute (never allocate)
    mixed_workers: int = 0            # alternate I/O and compute
    iters: int = 2000                 # cycles per I/O(/mixed) worker
    blocks_per_map: int = 8           # mapping size (32 KiB-file analogue)
    alloc_cost: float = 1.0           # virtual µs per map+access+unmap
    compute_quantum: float = 1.0      # virtual µs per compute op
    compute_factor: float = 1.0       # CF knob (§V-B): quanta per I/O op
    in_kernel_frac: float = 0.0       # fraction of stalls absorbed (Fig. 3)
    fpr: bool = True
    scoped: bool = False              # worker-scoped fences (off ⇒ the
                                      # paper's global-broadcast pessimism)
    scope: ContextScope = ContextScope.PER_GROUP
    shared_context: bool = False      # all workers share one recycling ctx
    fence_cost: float = 25.0          # initiator wait per fence (virtual µs)
    recv_stall: float = 0.2           # per-recipient stall (remote flush +
                                      # TLB refill tail; calibrated to the
                                      # paper's ~21% compute loss shape)
    storage_latency: float = 0.0      # extra µs per map (device latency)
    shard_table_bytes: int = 64 << 10  # device block-table bytes per worker
                                       # shard (re-uploaded when a fence
                                       # covers that worker)
    refresh_bw: float = 40e3          # shard re-upload bandwidth, bytes per
                                      # virtual µs (PCIe/ICI-ish ratio vs
                                      # the 25 µs fence base cost)
    reshard_iters: tuple = ()         # ((iteration, new_num_workers), …):
                                      # elastic topology changes applied
                                      # mid-run; each costs the *moved*
                                      # row fraction of the full table
                                      # (see device_refreshed_bytes),
                                      # never a cold re-upload
    islands: tuple = ()               # island partition of ALL workers
                                      # (io + mixed + compute) for the
                                      # two-level fence engine; () ⇒ flat.
                                      # Cross-island fences pay the cost
                                      # model's cross_island_cost multiple
                                      # of fence_cost (remote delta
                                      # propagation)
    seed: int = 0


@dataclass
class SimResult:
    io_ops: int = 0
    compute_ops: int = 0
    fences: int = 0
    fences_skipped: int = 0
    elided: int = 0
    io_time: float = 0.0
    compute_time: float = 0.0
    stall_time: float = 0.0
    evictions: int = 0
    device_refreshed_bytes: int = 0   # Σ shard bytes re-uploaded by fences
    refresh_time: float = 0.0         # virtual µs spent re-uploading shards
    reshards: int = 0                 # elastic topology changes applied
    reshard_moved_rows: int = 0       # table rows whose shard owner moved

    def throughput(self) -> float:
        t = max(self.io_time, 1e-9)
        return self.io_ops / t

    def compute_throughput(self) -> float:
        t = max(self.compute_time, 1e-9)
        return self.compute_ops / t

    def as_dict(self) -> dict:
        d = dict(self.__dict__)
        d["io_throughput"] = self.throughput()
        d["compute_throughput"] = self.compute_throughput()
        return d


class FenceImpactSim:
    """Deterministic virtual-time simulation of cases 1–5."""

    def __init__(self, cfg: SimConfig,
                 cost_model: FenceCostModel | None = None):
        self.cfg = cfg
        self.fences = FenceEngine(cost_model=cost_model, measure=False,
                                  scoped=cfg.scoped)
        self.mgr = FprMemoryManager(
            config=FprConfig(
                num_blocks=cfg.num_blocks,
                num_workers=max(1, cfg.io_workers + cfg.mixed_workers),
                fpr_enabled=cfg.fpr, scoped_fences=cfg.scoped),
            fence_engine=self.fences)
        # compute workers hold table replicas too (they are what a global
        # fence needlessly stalls); give them epoch slots after io+mixed
        total = max(1, cfg.io_workers + cfg.mixed_workers
                    + cfg.compute_workers)
        self.fences.ensure_workers(total)
        if cfg.islands:
            # the partition covers *all* workers (compute included), so it
            # is installed on each layer directly rather than through
            # mgr.set_topology (which validates against the manager's
            # io+mixed worker count)
            from repro.core.topology import Topology
            topo = Topology.of(cfg.islands, num_workers=total)
            if not topo.is_flat:
                self.mgr.tracker.set_topology(topo)
                self.fences.set_topology(topo)
                self.mgr.tables.set_topology(topo)
        self.res = SimResult()

    def run(self) -> SimResult:
        c = self.cfg
        res = self.res
        n_io = c.io_workers
        n_cp = c.compute_workers
        n_mx = c.mixed_workers

        def fence_stall(covered, cross=False):
            # every worker the fence covered is stalled for recv_stall
            # (remote flush + refills); the initiating worker waits
            # fence_cost for all confirmations (grows weakly with
            # recipient count — tree-ack).  A global fence covers every
            # worker; a scoped fence only its mask's popcount —
            # that difference is exactly the paper's observation that the
            # OS stalls cores that never cached the translation.
            # On top of the wait, each covered worker's device block-table
            # shard must be re-uploaded (shard_table_bytes / refresh_bw per
            # shard) — the per-shard device-refresh cost of the fence.
            absorbed = c.in_kernel_frac
            per_worker = c.recv_stall * (1.0 - absorbed)
            res.stall_time += per_worker * covered
            refreshed = covered * c.shard_table_bytes
            res.device_refreshed_bytes += refreshed
            refresh = refreshed / c.refresh_bw
            res.refresh_time += refresh
            import math
            base = c.fence_cost * (1 + 0.15 * math.log2(max(2, covered)))
            if cross:
                # the fence's scope spans islands: the initiator also waits
                # for remote-island delta propagation (the two-level
                # engine's configurable multiplier)
                base *= self.fences.cost_model.cross_island_cost
            return base + refresh

        fences_before = self.fences.stats.fences

        def io_op(wid, ctx_gid):
            wid %= self.mgr.num_workers       # topology may have shrunk
            ctx = (derive_context(c.scope, group_id=ctx_gid)
                   if c.fpr else None)
            st = self.fences.stats
            isl = self.fences.island_stats
            f0, w0 = st.fences, st.workers_covered
            x0 = isl.fences_cross if isl is not None else 0
            m = self.mgr.mmap(c.blocks_per_map, ctx, worker=wid)
            self.mgr.munmap(m.mapping_id, worker=wid)
            res.io_ops += 1
            cost = c.alloc_cost + c.storage_latency
            if st.fences > f0:
                cross = isl is not None and isl.fences_cross > x0
                cost += fence_stall(st.workers_covered - w0, cross)
            res.io_time += cost

        def reshard(new_workers):
            # per-shard refresh cost model, applied to the topology event
            # itself: only the moved row fraction of the full device table
            # is re-broadcast (a cold start would pay the whole table)
            old_workers = self.mgr.num_workers
            plan = self.mgr.reshard(new_workers)
            moved = len(plan["moved_slots"])
            frac = moved / max(1, self.mgr.tables.max_seqs)
            refreshed = int(frac * old_workers * c.shard_table_bytes)
            res.reshards += 1
            res.reshard_moved_rows += moved
            res.device_refreshed_bytes += refreshed
            refresh = refreshed / c.refresh_bw
            res.refresh_time += refresh
            res.io_time += refresh            # the initiator waits

        reshard_at = dict(c.reshard_iters)

        for it in range(c.iters):
            if it in reshard_at:
                reshard(reshard_at[it])
            # --- I/O workers: mmap → access → munmap ----------------------
            for w in range(n_io):
                io_op(w, 1 if c.shared_context else (w + 1))
            # --- compute workers: stalled only by fences ------------------
            if n_cp:
                res.compute_ops += n_cp
                res.compute_time += n_cp * c.compute_quantum
            # --- mixed workers: alternate -------------------------------
            for w in range(n_mx):
                io_op(n_io + w, 1 if c.shared_context else (100 + w))
                res.compute_ops += int(c.compute_factor)
                res.compute_time += c.compute_factor * c.compute_quantum

        st = self.fences.stats
        res.fences = st.fences - fences_before
        res.fences_skipped = st.skipped_at_free
        res.elided = st.elided_by_version
        isl = self.fences.island_stats
        if isl is not None:
            # attached only under a multi-island topology so flat-run
            # as_dict() keeps its pre-island key set bit for bit
            res.fences_intra = isl.fences_intra
            res.fences_cross = isl.fences_cross
            res.deltas_propagated = isl.deltas_propagated
        # compute workers absorb the accumulated stall into their time
        if n_cp or n_mx:
            res.compute_time += res.stall_time
        return res


def eviction_sim(cfg: SimConfig, *, working_set_factor: float = 10.0,
                 pg_buffer: int = 0,
                 watermarks: Watermarks | None = None) -> SimResult:
    """§V-B: threads randomly touch a mapping ≫ memory; kswapd evicts.

    ``pg_buffer`` models the per-thread local memory (PG) — each compute
    quantum touches it, and every fence's TLB flush forces page-walk
    refills proportional to the buffer size (the paper's PG effect).
    """
    rng = np.random.default_rng(cfg.seed)
    fences = FenceEngine(measure=False)
    mgr = FprMemoryManager(
        config=FprConfig(num_blocks=cfg.num_blocks, num_workers=1,
                         fpr_enabled=cfg.fpr,
                         max_blocks_per_seq=int(
                             cfg.num_blocks * working_set_factor) + 1),
        fence_engine=fences)
    res = SimResult()
    n_threads = max(1, cfg.mixed_workers)
    total_blocks = int(cfg.num_blocks * working_set_factor)
    ctx = derive_context(cfg.scope, group_id=1) if cfg.fpr else None
    m = mgr.mmap_sparse(total_blocks, ctx)

    victims_state = {"pos": 0}

    def victims():
        # LRU ring over the big mapping
        start = victims_state["pos"]
        for i in range(total_blocks):
            idx = (start + i) % total_blocks
            victims_state["pos"] = (idx + 1) % total_blocks
            yield m.mapping_id, idx, cfg.fpr

    ev = WatermarkEvictor(mgr, victims, watermarks=watermarks)

    for it in range(cfg.iters):
        for t in range(n_threads):
            idx = int(rng.integers(0, total_blocks))
            f0 = fences.stats.fences
            ev.maybe_evict()
            _, faulted = mgr.touch(m.mapping_id, idx)
            res.io_ops += 1
            cost = cfg.alloc_cost + (cfg.storage_latency if faulted else 0)
            fences_recv = fences.stats.fences - f0
            if fences_recv:
                stall = cfg.fence_cost * (1 - cfg.in_kernel_frac)
                stall += cfg.recv_stall * (n_threads - 1) * fences_recv
                # TLB refill for the PG buffer after each flush
                stall += pg_buffer * 0.05 * fences_recv
                # single-worker sim: every fence re-uploads one table shard
                refreshed = fences_recv * cfg.shard_table_bytes
                res.device_refreshed_bytes += refreshed
                res.refresh_time += refreshed / cfg.refresh_bw
                stall += refreshed / cfg.refresh_bw
                cost += stall
                res.stall_time += stall
            res.io_time += cost
            res.compute_ops += int(cfg.compute_factor)
            res.compute_time += cfg.compute_factor * cfg.compute_quantum
    res.compute_time += res.stall_time
    res.fences = fences.stats.fences
    res.fences_skipped = fences.stats.skipped_at_free
    res.elided = fences.stats.elided_by_version
    res.evictions = ev.stats.blocks_evicted
    return res


# ===================================================================== admission
@dataclass
class AdmissionSimConfig:
    """Virtual-time model of the admission/preemption subsystem.

    ``n_requests`` jobs drain through ``max_batch`` decode slots over a
    ``pool_blocks`` ledger (block_size 1: a job's window *is* its block
    count).  ``arrival_every = 0`` queues everything at t=0 (closed loop);
    ``> 0`` staggers arrivals by that many virtual steps — the open-loop
    shape where FCFS first-fit *starves* large windows (freshly freed
    capacity is re-nibbled by small late arrivals before a large window
    can accumulate) and the SLA/deadline policy's capacity holds bound the
    tail.  Each virtual step every running job decodes once;
    admission-queue latency is the steps a job spends queued after
    arriving.  With ``overcommit_ratio > 1`` the ledger admits
    optimistically and demand-pager pressure (committed > pool) preempts
    victims — ``recompute`` forfeits the victim's decoded progress plus a
    re-prefill, ``swap`` pays per-block transfer both ways but keeps
    progress — the same cost split the real engine's two victim strategies
    have.
    """

    pool_blocks: int = 64
    max_batch: int = 8
    n_requests: int = 64
    n_streams: int = 4
    priority_classes: int = 1          # >1 ⇒ jobs get seeded priorities
    policy: str = "fcfs"               # fcfs | recycle | priority | deadline
    preempt: str = "recompute"         # recompute | swap
    overcommit_ratio: float = 1.0
    window_lo: int = 2                 # job window, blocks (seeded uniform)
    window_hi: int = 8
    large_frac: float = 0.0            # >0 ⇒ bimodal mice-and-elephants mix:
                                       # window_hi with this probability,
                                       # else window_lo (the classic
                                       # first-fit starvation workload)
    steps_per_block: int = 4           # decode steps per window block
    step_time: float = 1.0             # virtual µs per engine step
    prefill_cost: float = 4.0          # virtual µs per (re-)prefill
    swap_cost_per_block: float = 0.5   # virtual µs per block swapped out+in
    sla_steps: float = 64.0            # deadline budget (virtual steps) for
                                       # the SLA-aware deadline policy
    arrival_every: float = 0.0         # virtual steps between arrivals
                                       # (0 ⇒ closed loop, all at t=0)
    chunk_blocks: int = 0              # >0 ⇒ chunked-prefill admission: a
                                       # job is admitted on its first chunk
                                       # (+1 tail block) and the ledger
                                       # reservation grows one chunk per
                                       # step — elephants take capacity
                                       # gradually instead of blocking the
                                       # whole window at admission, which
                                       # is what bounds mice queue-wait.
                                       # 0 ⇒ monolithic full-window admits.
    num_workers: int = 1               # ledger shares (per-worker splits)
    reshard_iters: tuple = ()          # ((step, new_num_workers), …):
                                       # mid-run topology changes; the
                                       # governor's reshard remaps ledger
                                       # shares, and reshard-aware policies
                                       # see the upcoming distance
                                       # (note_reshard_distance) to defer
                                       # elephant chunk growth across it
    seed: int = 0


@dataclass
class _SimJob:
    rid: int
    stream: str
    priority: int
    window: int
    service_steps: int
    arrival: int = 0                   # virtual arrival ordinal (EDF key)
    sla: "float | None" = None         # deadline budget (deadline policy)
    prompt: range = range(0)           # governor reads len(prompt)+max_new
    max_new_tokens: int = 0
    done_steps: int = 0
    wait_steps: int = 0
    swapped: bool = False
    mapping: "object | None" = None    # swap-preempted holder: admit_blocks
                                       # must re-reserve the held blocks,
                                       # never a fresh chunk estimate

    def __post_init__(self) -> None:
        self.prompt = range(self.window)     # block_size 1 ⇒ window blocks


@dataclass
class _HeldBlocks:
    """What a swap-preempted sim job still holds (mapping stand-in)."""

    num_blocks: int
    prefix_hits: int = 0


def admission_sim(cfg: AdmissionSimConfig) -> dict:
    """Deterministic admission/preemption sweep point (virtual time)."""
    rng = np.random.default_rng(cfg.seed)
    gov = MemoryGovernor(
        cfg.pool_blocks, block_size=1, num_workers=cfg.num_workers,
        config=GovernorConfig(policy=cfg.policy, preempt=cfg.preempt,
                              overcommit_ratio=cfg.overcommit_ratio))
    gov.chunk_blocks = cfg.chunk_blocks or None
    jobs = []
    for i in range(cfg.n_requests):
        if cfg.large_frac > 0:
            w = (cfg.window_hi if rng.random() < cfg.large_frac
                 else cfg.window_lo)
        else:
            w = int(rng.integers(cfg.window_lo, cfg.window_hi + 1))
        jobs.append(_SimJob(
            rid=i + 1, stream=f"s{i % cfg.n_streams}",
            priority=int(rng.integers(0, max(1, cfg.priority_classes))),
            window=w, service_steps=w * cfg.steps_per_block,
            arrival=int(i * cfg.arrival_every) + 1 if cfg.arrival_every
            else i + 1, sla=cfg.sla_steps))
    if cfg.arrival_every:
        pending = list(jobs)            # arrive over virtual time
        queue: list[_SimJob] = []
    else:
        pending = []
        queue = list(jobs)              # closed loop: all queued at t=0
    running: dict[int, _SimJob] = {}
    done: list[_SimJob] = []
    overhead = 0.0                      # prefill + swap virtual time
    wasted_steps = 0                    # decode work forfeited by recompute
    steps = 0

    def preempt(victim: _SimJob) -> None:
        nonlocal overhead, wasted_steps
        slot = next(s for s, j in running.items() if j is victim)
        held = (gov.ledger.entries[victim.rid].blocks
                if gov.ledger.holds(victim.rid) else victim.window)
        del running[slot]
        gov.on_release(victim)
        if cfg.preempt == "swap":
            overhead += held * cfg.swap_cost_per_block
            victim.swapped = True
            # re-admission must re-reserve exactly what the victim still
            # holds (its blocks fault back in full), not a chunk estimate
            victim.mapping = _HeldBlocks(held)
        else:
            wasted_steps += victim.done_steps
            victim.done_steps = 0      # chunked growth restarts from 0 too
            victim.mapping = None
        gov.count_preempt(cfg.preempt)
        queue.insert(0, victim)

    def grow(job: _SimJob, n: int) -> bool:
        """Grow ``job``'s reservation by ``n`` blocks; False = the chunk
        stalls this step.  The sim's growth *waits* for freed capacity
        rather than preempting seated jobs — evicting a seated mouse to
        grow an elephant would invert every ordering the policies encode
        (the real engine escalates through its evictor first, which the
        block-ledger sim has no analogue for)."""
        try:
            gov.on_extend(job, n)
            return True
        except CapacityError:
            return False

    reshard_at = dict(cfg.reshard_iters)
    workers = cfg.num_workers
    reshards = 0

    while pending or queue or running:
        steps += 1
        if steps > 1_000_000:
            raise RuntimeError("admission_sim failed to drain — "
                               "a job can never be admitted")
        while pending and pending[0].arrival <= steps:
            queue.append(pending.pop(0))
        # --- elastic topology: remap ledger shares, advertise distance ---
        if steps in reshard_at:
            new_w = reshard_at[steps]
            gov.reshard(new_w, [w % new_w for w in range(workers)])
            workers = new_w
            reshards += 1
        upcoming = [s for s in reshard_at if s > steps]
        gov.note_reshard_distance(
            min(upcoming) - steps if upcoming else None)
        # --- priority pressure: evict lower classes for a blocked one ----
        while True:
            bi = gov.wants_priority_preempt(queue)
            if bi is None:
                break
            victim = gov.choose_victim(
                running, below_priority=queue[bi].priority)
            if victim is None:
                break
            preempt(victim)
        # --- chunked growth: reservations track written blocks -----------
        # A chunk-admitted job holds only what it has written plus
        # ``chunk_blocks + 1`` of headroom; its service fills one window
        # block per ``steps_per_block``, so elephants take capacity
        # gradually across their whole service instead of locking the
        # full window at admission (what starves mice monolithically).
        # Growers run *before* admission: freed capacity reaches a
        # partially-grown sequence ahead of the queue by default, and it
        # is the policy's defer_growth that explicitly yields a step's
        # headroom to a more urgent queued mouse (or parks growth across
        # an imminent reshard) — ranking growers vs mice is policy, not
        # loop order.
        if cfg.chunk_blocks:
            def can_write(j: _SimJob) -> bool:
                held_j = gov.ledger.entries[j.rid].blocks
                return j.done_steps < held_j * cfg.steps_per_block
            for slot, job in list(running.items()):
                if running.get(slot) is not job:
                    continue
                held = gov.ledger.entries[job.rid].blocks
                target = min(job.done_steps // cfg.steps_per_block
                             + cfg.chunk_blocks + 1, job.window)
                n = target - held
                if n <= 0:
                    continue
                if gov.defer_growth(job, n, queue):
                    continue           # policy yields the step's headroom
                while not grow(job, n):
                    # a stalled growth normally just waits for a decoder
                    # to release capacity — but when *every* runner is a
                    # stalled grower nothing will ever release, and the
                    # pool deadlocks; escalate to preemption (the
                    # engine's evict→preempt ladder) to keep it live
                    if any(j is not job and can_write(j)
                           for j in running.values()):
                        break
                    victim = (gov.choose_victim(running,
                                                exclude=(job.rid,))
                              if len(running) > 1 else None)
                    if victim is None:
                        break
                    preempt(victim)
        # --- admission (policy order, ledger-checked) --------------------
        while len(running) < cfg.max_batch:
            idx = gov.select(queue)
            if idx is None:
                break
            job = queue.pop(idx)
            slot = next(s for s in range(cfg.max_batch) if s not in running)
            running[slot] = job
            gov.on_admit(job, slot)
            job.mapping = None  # reservation re-seated; holder consumed
            if job.swapped:     # fault-back; out+in paid at preempt time
                job.swapped = False
            else:
                overhead += cfg.prefill_cost
        # --- pager pressure: over-committed ⇒ preempt (vLLM give-up fix) -
        while gov.ledger.committed > cfg.pool_blocks and len(running) > 1:
            victim = gov.choose_victim(running)
            if victim is None:
                break
            preempt(victim)
        # --- decode + queue latency -------------------------------------
        for slot, job in list(running.items()):
            if (cfg.chunk_blocks and job.done_steps
                    >= gov.ledger.entries[job.rid].blocks
                    * cfg.steps_per_block):
                continue           # out of reserved blocks — stalled grower
            job.done_steps += 1
            if job.done_steps >= job.service_steps:
                del running[slot]
                gov.on_release(job)
                done.append(job)
        for job in queue:
            job.wait_steps += 1

    waits = [j.wait_steps * cfg.step_time for j in jobs]
    # mice = the small-window class of the bimodal mix (everyone, when the
    # workload is unimodal) — their tail is what chunked admission and the
    # deadline policy's holds are protecting
    mice = ([j for j in jobs if j.window == cfg.window_lo]
            if cfg.large_frac > 0 else jobs)
    mice_waits = ([j.wait_steps * cfg.step_time for j in mice] or [0.0])
    g = gov.stats
    return {
        "policy": cfg.policy, "preempt": cfg.preempt,
        "overcommit_ratio": cfg.overcommit_ratio,
        "chunk_blocks": cfg.chunk_blocks,
        "completed": len(done),
        "makespan": steps * cfg.step_time,
        "queue_wait_mean": round(float(np.mean(waits)), 3),
        "queue_wait_p99": round(float(np.percentile(waits, 99)), 3),
        "queue_wait_max": round(float(np.max(waits)), 3),
        "queue_wait_mean_mice": round(float(np.mean(mice_waits)), 3),
        "queue_wait_p99_mice": round(float(np.percentile(mice_waits, 99)),
                                     3),
        "chunk_grows": g.chunk_grows,
        "reshards": reshards,
        "preemptions_recompute": g.preemptions_recompute,
        "preemptions_swap": g.preemptions_swap,
        "rejected_overcommit": g.rejected_overcommit,
        "holds": g.holds,
        "affinity_hit_rate": g.affinity_hit_rate,
        "wasted_decode_steps": wasted_steps,
        "preempt_overhead": round(overhead, 3),
        "peak_committed": gov.ledger.peak_committed,
        "pool_blocks": cfg.pool_blocks,
    }
