"""Device-facing paged KV cache bound to the FPR memory manager.

The split of responsibilities mirrors the paper exactly:

  * ``FprMemoryManager`` (core/) is the *kernel*: physical block ownership,
    recycling tracking, fence policy, eviction.
  * ``PagedKVCache`` is the *device side*: the pools live as JAX arrays, and
    the per-step (tables, lengths) tensors are assembled from the manager's
    mappings.  A coherence fence invalidates device table copies (epoch
    bump); the measured fence callback drains in-flight computation and
    re-uploads the tables — the TLB-flush analogue whose cost FPR avoids.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.block_table import Mapping
from repro.core.contexts import ContextRegistry, ContextScope
from repro.core.fpr import FprMemoryManager
from repro.core.shootdown import FenceCostModel, FenceEngine
from repro.models import transformer as tfm
from repro.models.config import ModelConfig


class PagedKVCache:
    def __init__(self, cfg: ModelConfig, num_blocks: int, max_batch: int,
                 max_seq_len: int, *, fpr_enabled: bool = True,
                 scope: ContextScope = ContextScope.PER_GROUP,
                 dtype=jnp.float32, num_workers: int = 1,
                 scoped_fences: bool = True,
                 cost_model: FenceCostModel | None = None):
        self.cfg = cfg
        self.block_size = tfm.BLOCK_SIZE
        self.max_batch = max_batch
        self.max_blocks_per_seq = -(-max_seq_len // self.block_size)
        self.fences = FenceEngine(cost_model=cost_model,
                                  on_fence=self._device_fence,
                                  num_workers=num_workers,
                                  scoped=scoped_fences)
        self.mgr = FprMemoryManager(
            num_blocks, num_workers=num_workers, max_seqs=max_batch * 4,
            max_blocks_per_seq=self.max_blocks_per_seq,
            fence_engine=self.fences, fpr_enabled=fpr_enabled,
            scoped_fences=scoped_fences)
        self.num_workers = num_workers
        self.contexts = ContextRegistry(default_scope=scope)
        self.fpr_enabled = fpr_enabled
        # device pools (decode-state pytree minus tables/lengths)
        spec = tfm.cache_spec(cfg, max_batch, max_seq_len,
                              num_blocks=num_blocks, dtype=dtype)
        self.state = {k: jnp.zeros(sh, dt) for k, (sh, dt) in spec.items()}
        self.state["tables"] = jnp.full(
            (max_batch, self.max_blocks_per_seq), -1, jnp.int32)
        self.state["lengths"] = jnp.zeros((max_batch,), jnp.int32)
        self._fence_drains = 0
        # swap "device": evicted block contents round-trip through host
        # memory (the storage behind the page cache; latency is real)
        self._swap_store: dict = {}
        self._pool_keys = [k for k in self.state
                           if k in ("k", "v", "mla_c", "mla_rope")]
        self.mgr.on_swap_out = self._swap_out
        self.mgr.on_swap_in = self._swap_in

    def _swap_out(self, mid: int, idx: int, phys: int) -> None:
        self._swap_store[(mid, idx)] = {
            key: np.asarray(self.state[key][:, phys])
            for key in self._pool_keys}

    def _swap_in(self, mid: int, idx: int, phys: int) -> None:
        data = self._swap_store.pop((mid, idx), None)
        if data is None:
            return
        for key, rows in data.items():
            self.state[key] = self.state[key].at[:, phys].set(
                jnp.asarray(rows))

    # -------------------------------------------------- measured fence cost
    def _device_fence(self, reason: str, n_blocks: int) -> None:
        """Drain in-flight steps + re-upload tables (the shootdown cost)."""
        jax.block_until_ready(self.state["tables"])
        tab, _ = self.mgr.tables.packed()
        self.state["tables"] = jax.device_put(
            jnp.asarray(tab[:self.max_batch], jnp.int32))
        self._fence_drains += 1

    # ---------------------------------------------------------- allocation
    def alloc_sequence(self, n_tokens: int, *, stream: str = "default",
                       group_id: int | None = None,
                       use_fpr: bool | None = None,
                       worker: int = 0) -> Mapping:
        n_blocks = max(1, -(-n_tokens // self.block_size))
        gid = group_id if group_id is not None else 1
        ctx = self.contexts.resolve(
            group_id=gid, stream_name=stream,
            use_fpr=self.fpr_enabled if use_fpr is None else use_fpr)
        return self.mgr.mmap(n_blocks, ctx, worker=worker)

    def extend_sequence(self, m: Mapping, n_blocks: int = 1, *,
                        worker: int = 0) -> None:
        self.mgr.extend(m.mapping_id, n_blocks, worker=worker)

    def free_sequence(self, m: Mapping, *, worker: int = 0) -> None:
        self.mgr.munmap(m.mapping_id, worker=worker)

    # ------------------------------------------------------- device tensors
    def slot_tables(self, mappings: dict[int, Mapping]) -> jax.Array:
        """Build the (max_batch, M) device table from slot → mapping."""
        tab = np.full((self.max_batch, self.max_blocks_per_seq), -1,
                      np.int32)
        for slot, m in mappings.items():
            n = min(len(m.physical), self.max_blocks_per_seq)
            tab[slot, :n] = [b if b >= 0 else -1 for b in m.physical[:n]]
        return jnp.asarray(tab)

    def update_tables(self, mappings: dict[int, Mapping],
                      lengths: np.ndarray) -> None:
        self.state["tables"] = self.slot_tables(mappings)
        self.state["lengths"] = jnp.asarray(lengths, jnp.int32)

    def counters(self) -> dict:
        d = self.mgr.counters()
        d["device_fence_drains"] = self._fence_drains
        return d
