"""Device-facing paged KV cache bound to the FPR memory manager.

The split of responsibilities mirrors the paper exactly:

  * ``FprMemoryManager`` (core/) is the *kernel*: physical block ownership,
    recycling tracking, fence policy, eviction.
  * ``PagedKVCache`` is the *device side*: the pools live as JAX arrays, and
    the per-step (tables, lengths) tensors are assembled from the manager's
    mappings.  A coherence fence invalidates device table copies (epoch
    bump); the cache subscribes to :class:`~repro.core.events.FenceIssued`
    on the stack's event bus and its handler drains in-flight computation
    and re-uploads the tables — the TLB-flush analogue whose cost FPR
    avoids (each refresh is published back as
    :class:`~repro.core.events.ShardRefreshed`).

**Sharded device tables.**  The device block-table is split into one shard
per worker: shard ``w`` holds the batch slots with ``slot % num_workers ==
w``, each shard is its own device array, and the kernel-facing
``state["tables"]`` tensor is assembled from the shard arrays.  The engine
binds each slot to its serving worker at admission
(:meth:`bind_slot_worker`); a *scoped* fence re-uploads the covered
workers' own shards plus the shards of every slot bound to them, so
non-slot routings (stream affinity) stay covered — refreshed bytes scale
with the mask popcount — while
a *global* fence (or ``workers=None``) falls back to re-uploading every
shard, reproducing the broadcast pessimism the paper eliminates.  The
per-shard refresh counters (``device_refreshed_entries/bytes``,
``device_shard_refreshes``, ``device_full_refreshes``) are what the
benchmarks diff between the global and sharded paths.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.block_table import Mapping
from repro.core.config import FprConfig
from repro.core.contexts import ContextRegistry, ContextScope
from repro.core.events import (EventBus, FenceIssued, ShardRefreshed,
                               SwapDropped)
from repro.core.fpr import FprMemoryManager
from repro.core.shootdown import FenceCostModel, FenceEngine
from repro.models import transformer as tfm
from repro.models.config import ModelConfig


class PagedKVCache:
    def __init__(self, cfg: ModelConfig, num_blocks: int, max_batch: int,
                 max_seq_len: int, *, fpr_enabled: bool = True,
                 scope: ContextScope = ContextScope.PER_GROUP,
                 dtype=jnp.float32, num_workers: int = 1,
                 scoped_fences: bool = True,
                 cost_model: FenceCostModel | None = None):
        self.cfg = cfg
        self.block_size = tfm.BLOCK_SIZE
        self.max_batch = max_batch
        self.max_blocks_per_seq = -(-max_seq_len // self.block_size)
        self.bus = EventBus()
        self.fences = FenceEngine(cost_model=cost_model,
                                  num_workers=num_workers,
                                  scoped=scoped_fences, bus=self.bus)
        # The manager subscribes its table-epoch bump first (coherence
        # order: host epochs move before the device shards refresh).
        self.mgr = FprMemoryManager(
            config=FprConfig(num_blocks=num_blocks, num_workers=num_workers,
                             max_seqs=max_batch * 4,
                             max_blocks_per_seq=self.max_blocks_per_seq,
                             fpr_enabled=fpr_enabled,
                             scoped_fences=scoped_fences),
            fence_engine=self.fences)
        self.metrics = self.mgr.metrics
        self.metrics.register("device", self._device_metrics)
        self.num_workers = num_workers
        self.contexts = ContextRegistry(default_scope=scope)
        self.fpr_enabled = fpr_enabled
        # device pools (decode-state pytree minus tables/lengths)
        spec = tfm.cache_spec(cfg, max_batch, max_seq_len,
                              num_blocks=num_blocks, dtype=dtype)
        self.state = {k: jnp.zeros(sh, dt) for k, (sh, dt) in spec.items()}
        # Sharded device block-table: worker w owns slots w, w+W, w+2W, …
        # (one shard array per worker; the monolithic tensor the kernel
        # consumes is assembled from the shards, never rebuilt from host).
        self.num_shards = max(1, num_workers)
        self._shard_slots = [
            np.arange(w, max_batch, self.num_shards, dtype=np.int64)
            for w in range(self.num_shards)]
        # mirror of the last-uploaded device table (scheduler-slot space) —
        # what the device currently holds, used to diff per-step uploads
        self._host_tables = np.full(
            (max_batch, self.max_blocks_per_seq), -1, np.int32)
        # last known slot → mapping binding (refreshed by update_tables);
        # the fence path re-derives authoritative rows from the *live*
        # mapping state, so a mid-step fence uploads post-fence tables
        # rather than re-broadcasting the previous step's rows
        self._slot_mappings: dict[int, Mapping] = {}
        # which worker currently serves each batch slot (the engine rebinds
        # this at admission; defaults to the slot-modulo shard layout) —
        # scoped refreshes cover the shards of every slot a covered worker
        # serves, so non-slot routings (e.g. stream affinity) stay sound
        self._slot_worker = np.arange(max_batch,
                                      dtype=np.int64) % self.num_shards
        self._shard_tables = [
            jnp.full((len(s), self.max_blocks_per_seq), -1, jnp.int32)
            for s in self._shard_slots]
        self.state["tables"] = self._assemble_tables()
        self.state["lengths"] = jnp.zeros((max_batch,), jnp.int32)
        self._fence_drains = 0
        self._full_refreshes = 0        # global fences: every shard re-upload
        self._shard_refreshes = 0       # scoped fences: masked shards only
        self._refreshed_entries = 0     # table entries re-uploaded by fences
        self._refreshed_bytes = 0
        self._step_upload_entries = 0   # normal-path (non-fence) shard uploads
        # swap "device": evicted block contents round-trip through host
        # memory (the storage behind the page cache; latency is real)
        self._swap_store: dict = {}
        self._pool_keys = [k for k in self.state
                           if k in ("k", "v", "mla_c", "mla_rope")]
        self.mgr.on_swap_out = self._swap_out
        self.mgr.on_swap_in = self._swap_in
        # event-bus subscriptions: the measured device-shard refresh runs on
        # every fence (after the manager's epoch bump, which subscribed
        # first), and dying mappings' swap-store copies are dropped
        self.bus.subscribe(FenceIssued, self._on_fence_issued)
        self.bus.subscribe(SwapDropped, self._handle_swap_dropped)

    def _on_fence_issued(self, evt: FenceIssued) -> None:
        self._device_fence(evt.reason, evt.n_blocks, evt.workers)

    def _handle_swap_dropped(self, evt: SwapDropped) -> None:
        """Mapping destroyed with this block swapped out — free the copy."""
        self._swap_store.pop((evt.mapping_id, evt.logical_idx), None)

    def _swap_out(self, mid: int, idx: int, phys: int) -> None:
        self._swap_store[(mid, idx)] = {
            key: np.asarray(self.state[key][:, phys])
            for key in self._pool_keys}

    def _swap_in(self, mid: int, idx: int, phys: int) -> None:
        data = self._swap_store.pop((mid, idx), None)
        if data is None:
            return
        for key, rows in data.items():
            self.state[key] = self.state[key].at[:, phys].set(
                jnp.asarray(rows))

    # -------------------------------------------------- measured fence cost
    def bind_slot_worker(self, slot: int, worker: int) -> None:
        """Record which worker serves ``slot`` (engine routing update)."""
        self._slot_worker[slot] = int(worker) % self.num_shards

    def _shards_of(self, workers) -> list[int]:
        """Worker ids → device-table shard indices to refresh.

        Covers the workers' own shards plus the shard of every batch slot
        currently bound to a covered worker — under non-slot routing a
        worker's rows can live outside its modulo shard, and those rows are
        exactly what its in-flight dispatches captured.
        """
        covered = {int(w) % self.num_shards for w in workers}
        shards = set(covered)
        bound = np.nonzero(np.isin(self._slot_worker,
                                   np.asarray(sorted(covered))))[0]
        shards.update(int(s) % self.num_shards for s in bound)
        return sorted(shards)

    def _assemble_tables(self) -> jax.Array:
        """The kernel-facing (max_batch, M) tensor, built from shard arrays."""
        if self.num_shards == 1:
            return self._shard_tables[0]
        tab = jnp.full((self.max_batch, self.max_blocks_per_seq), -1,
                       jnp.int32)
        for slots, shard in zip(self._shard_slots, self._shard_tables):
            tab = tab.at[slots].set(shard)
        return tab

    def _device_fence(self, reason: str, n_blocks: int,
                      workers=None) -> None:
        """Drain in-flight steps + re-upload table shards (shootdown cost).

        A global fence (``workers is None``) re-uploads *every* shard — the
        paper's broadcast pessimism.  A scoped fence re-uploads only the
        shards of the workers it covered; everyone else's device copy stays
        valid (their shard epoch did not move), so refreshed bytes scale
        with the fence's mask popcount instead of the worker count.
        """
        jax.block_until_ready(self.state["tables"])      # the drain
        shards = (range(self.num_shards) if workers is None
                  else self._shards_of(workers))
        # Authoritative post-fence rows: re-derive from the mappings that
        # are still live in the manager (a fence can fire mid-step — after
        # an alloc/evict/free but before the next update_tables — so the
        # last-uploaded mirror lags reality).  Only the covered shards'
        # slots are rebuilt: host-side fence work scales with the mask
        # popcount, like the upload it feeds.
        alive = self.mgr.tables.mappings
        entries = nbytes = 0
        for w in shards:
            slots = self._shard_slots[w]
            rows = np.full((len(slots), self.max_blocks_per_seq), -1,
                           np.int32)
            for i, s in enumerate(slots):
                m = self._slot_mappings.get(int(s))
                if m is not None and m.mapping_id in alive:
                    self._fill_row(rows[i], m)
            self._host_tables[slots] = rows              # device now has them
            self._shard_tables[w] = jax.device_put(
                jnp.asarray(rows, jnp.int32))
            entries += rows.size
            nbytes += rows.nbytes
        self._refreshed_entries += entries
        self._refreshed_bytes += nbytes
        self.state["tables"] = self._assemble_tables()
        self._fence_drains += 1
        if workers is None:
            self._full_refreshes += 1
        else:
            self._shard_refreshes += 1
        if self.bus.wants(ShardRefreshed):
            self.bus.publish(ShardRefreshed(
                reason=reason, shards=tuple(int(s) for s in shards),
                entries=entries, nbytes=nbytes, full=workers is None))

    # ---------------------------------------------------------- allocation
    def alloc_sequence(self, n_tokens: int, *, stream: str = "default",
                       group_id: int | None = None,
                       use_fpr: bool | None = None,
                       worker: int = 0) -> Mapping:
        n_blocks = max(1, -(-n_tokens // self.block_size))
        gid = group_id if group_id is not None else 1
        ctx = self.contexts.resolve(
            group_id=gid, stream_name=stream,
            use_fpr=self.fpr_enabled if use_fpr is None else use_fpr)
        return self.mgr.mmap(n_blocks, ctx, worker=worker)

    def extend_sequence(self, m: Mapping, n_blocks: int = 1, *,
                        worker: int = 0) -> None:
        self.mgr.extend(m.mapping_id, n_blocks, worker=worker)

    def free_sequence(self, m: Mapping, *, worker: int = 0) -> None:
        self.mgr.munmap(m.mapping_id, worker=worker)

    # ------------------------------------------------------- device tensors
    def _fill_row(self, row: np.ndarray, m: Mapping) -> None:
        """Write a mapping's physical blocks into a (pre-cleared) table row."""
        n = min(len(m.physical), self.max_blocks_per_seq)
        row[:n] = [b if b >= 0 else -1 for b in m.physical[:n]]

    def _host_rows(self, mappings: dict[int, Mapping]) -> np.ndarray:
        """Host (max_batch, M) table from slot → mapping."""
        tab = np.full((self.max_batch, self.max_blocks_per_seq), -1,
                      np.int32)
        for slot, m in mappings.items():
            self._fill_row(tab[slot], m)
        return tab

    def slot_tables(self, mappings: dict[int, Mapping]) -> jax.Array:
        """A standalone (max_batch, M) device table (prefill temp views)."""
        return jnp.asarray(self._host_rows(mappings))

    def update_tables(self, mappings: dict[int, Mapping],
                      lengths: np.ndarray) -> None:
        """Per-step table update: upload only the shards whose rows changed,
        then assemble the kernel tensor from the shard arrays."""
        self._slot_mappings = dict(mappings)
        host = self._host_rows(mappings)
        for w, slots in enumerate(self._shard_slots):
            rows = host[slots]
            if not np.array_equal(rows, self._host_tables[slots]):
                self._shard_tables[w] = jnp.asarray(rows)
                self._step_upload_entries += rows.size
        self._host_tables = host
        self.state["tables"] = self._assemble_tables()
        self.state["lengths"] = jnp.asarray(lengths, jnp.int32)

    def _device_metrics(self) -> dict:
        return {"fence_drains": self._fence_drains,
                "table_shards": self.num_shards,
                "full_refreshes": self._full_refreshes,
                "shard_refreshes": self._shard_refreshes,
                "refreshed_entries": self._refreshed_entries,
                "refreshed_bytes": self._refreshed_bytes,
                "step_upload_entries": self._step_upload_entries}

    def counters(self) -> dict:
        """Legacy nested counter view (see :meth:`FprMemoryManager.counters`);
        new code reads ``self.metrics.snapshot()``."""
        from repro.core.metrics import legacy_view
        return legacy_view(self.metrics.snapshot())
