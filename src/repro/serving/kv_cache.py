"""Device-facing paged KV cache bound to the FPR memory manager.

The split of responsibilities mirrors the paper exactly:

  * ``FprMemoryManager`` (core/) is the *kernel*: physical block ownership,
    recycling tracking, fence policy, eviction.
  * ``PagedKVCache`` is the *device side*: the pools live as JAX arrays, and
    the per-step (tables, lengths) tensors are assembled from the manager's
    mappings.  A coherence fence invalidates device table copies (epoch
    bump); the cache subscribes to :class:`~repro.core.events.FenceIssued`
    on the stack's event bus and its handler drains in-flight computation
    and re-uploads the tables — the TLB-flush analogue whose cost FPR
    avoids (each refresh is published back as
    :class:`~repro.core.events.ShardRefreshed`).

**Shard-native device tables.**  The device block-table lives as ONE
stacked ``(num_workers, Bs, M)`` int32 array (``state["tables"]``): shard
``w`` is slice ``[w]`` and holds the batch slots with ``slot % W == w`` at
local row ``slot // W`` (``Bs = ceil(max_batch / W)``; slots past
``max_batch`` pad with ``-1`` and are never read).  The decode kernels
walk this stack *directly* (see ``kernels/paged_attention``) — there is no
monolithic kernel tensor and therefore no O(full-table) assemble anywhere:
a per-step update or a scoped fence refresh is one ``at[w].set`` slice
update per touched shard, and the engine binds each slot to its serving
worker at admission (:meth:`bind_slot_worker`) so a *scoped* fence
re-uploads the covered workers' own shards plus the shards of every slot
bound to them.  A *global* fence (``workers=None``) falls back to
re-uploading every shard, reproducing the broadcast pessimism the paper
eliminates.  The per-shard refresh counters (``device_refreshed_entries/
bytes``, ``device_shard_refreshes``, ``device_full_refreshes``) are what
the benchmarks diff between the global and sharded paths.

**Elastic resharding.**  :meth:`reshard` changes the worker topology of a
*live* cache: the manager carries masks/epochs/slots across (see
``core/fpr.py``), the cache repartitions the stacked array — re-deriving
authoritative rows only for the slots whose shard owner *moved* — and the
manager's scoped ``reason="reshard"`` fence (fired only when live rows
moved) bumps the old owners' epochs.  The cache skips its own device
refresh for that one fence (``_in_reshard``): the repartition that just
ran *is* the refresh, already counted under the ``device.reshard_*``
counters, so refreshed bytes scale with the moved fraction instead of a
full-table cold start.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.block_table import Mapping
from repro.core.config import FprConfig
from repro.core.contexts import ContextRegistry, ContextScope
from repro.core.events import (EventBus, FenceIssued, ShardRefreshed,
                               SwapDropped, TopologyChanged)
from repro.core.fpr import FprMemoryManager
from repro.core.prefix import block_hashes
from repro.core.shootdown import FenceCostModel, FenceEngine
from repro.models import transformer as tfm
from repro.models.config import ModelConfig


class PagedKVCache:
    def __init__(self, cfg: ModelConfig, num_blocks: int, max_batch: int,
                 max_seq_len: int, *, fpr_enabled: bool = True,
                 scope: ContextScope = ContextScope.PER_GROUP,
                 dtype=jnp.float32, num_workers: int = 1,
                 islands=None,
                 scoped_fences: bool = True,
                 prefix_sharing: bool = True,
                 cost_model: FenceCostModel | None = None):
        self.cfg = cfg
        self.block_size = tfm.BLOCK_SIZE
        self.max_batch = max_batch
        self.max_blocks_per_seq = -(-max_seq_len // self.block_size)
        self.bus = EventBus()
        self.fences = FenceEngine(cost_model=cost_model,
                                  num_workers=num_workers,
                                  scoped=scoped_fences, bus=self.bus)
        # The manager subscribes its table-epoch bump first (coherence
        # order: host epochs move before the device shards refresh).
        self.mgr = FprMemoryManager(
            config=FprConfig(num_blocks=num_blocks, num_workers=num_workers,
                             islands=islands,
                             max_seqs=max_batch * 4,
                             max_blocks_per_seq=self.max_blocks_per_seq,
                             fpr_enabled=fpr_enabled,
                             scoped_fences=scoped_fences,
                             prefix_sharing=prefix_sharing),
            fence_engine=self.fences)
        self.metrics = self.mgr.metrics
        self.metrics.register("device", self._device_metrics)
        self.num_workers = num_workers
        self.contexts = ContextRegistry(default_scope=scope)
        self.fpr_enabled = fpr_enabled
        # device pools (decode-state pytree minus tables/lengths)
        spec = tfm.cache_spec(cfg, max_batch, max_seq_len,
                              num_blocks=num_blocks, dtype=dtype)
        self.state = {k: jnp.zeros(sh, dt) for k, (sh, dt) in spec.items()}
        # mirror of the last-uploaded device table (scheduler-slot space) —
        # what the device currently holds, used to diff per-step uploads
        self._host_tables = np.full(
            (max_batch, self.max_blocks_per_seq), -1, np.int32)
        # last known slot → mapping binding (refreshed by update_tables);
        # the fence path re-derives authoritative rows from the *live*
        # mapping state, so a mid-step fence uploads post-fence tables
        # rather than re-broadcasting the previous step's rows
        self._slot_mappings: dict[int, Mapping] = {}
        self._init_shard_layout(num_workers)
        self.state["tables"] = self._stack_from_host()
        self.state["lengths"] = jnp.zeros((max_batch,), jnp.int32)
        self._fence_drains = 0
        self._full_refreshes = 0        # global fences: every shard re-upload
        self._shard_refreshes = 0       # scoped fences: masked shards only
        self._refreshed_entries = 0     # table entries re-uploaded by fences
        self._refreshed_bytes = 0
        self._step_upload_entries = 0   # normal-path (non-fence) shard uploads
        self._reshards = 0              # elastic topology changes applied
        self._reshard_moved_entries = 0
        self._reshard_refreshed_bytes = 0
        self._in_reshard = False
        # Per-island replica groups (numaPTE): a scoped fence re-uploads
        # in full only the shards inside the covered islands; shards it
        # must bump in *remote* islands take the delta-propagation path
        # (same data lands on the device, accounted apart so the
        # cross-island refreshed-bytes win is measurable).  Materialised
        # lazily on the first multi-island fence; None keeps flat
        # snapshots key-identical to the pre-island cache.
        self._island_device: "dict | None" = None
        # swap "device": evicted block contents round-trip through host
        # memory (the storage behind the page cache; latency is real)
        self._swap_store: dict = {}
        # "kv" is the fused head-interleaved K/V pool — swap, COW
        # divergence and shard refresh all move ONE contiguous array per
        # block instead of separate K and V halves
        self._pool_keys = [k for k in self.state
                           if k in ("kv", "mla_c", "mla_rope")]
        self.mgr.on_swap_out = self._swap_out
        self.mgr.on_swap_in = self._swap_in
        # event-bus subscriptions: the measured device-shard refresh runs on
        # every fence (after the manager's epoch bump, which subscribed
        # first), topology changes repartition the shard stack, and dying
        # mappings' swap-store copies are dropped
        self.bus.subscribe(FenceIssued, self._on_fence_issued)
        self.bus.subscribe(TopologyChanged, self._on_topology_changed)
        self.bus.subscribe(SwapDropped, self._handle_swap_dropped)

    # --------------------------------------------------------- shard layout
    def _init_shard_layout(self, num_workers: int) -> None:
        """(Re)build the interleaved slot→shard partition for ``W`` workers.

        Shard ``w`` owns slots ``w, w+W, w+2W, …``; the stacked device
        array is ``(W, Bs, M)`` with ``Bs = ceil(max_batch / W)`` (tail
        rows of ragged shards stay ``-1`` and are never addressed — the
        kernels index slots ``< max_batch`` only).
        """
        self.num_shards = max(1, num_workers)
        self.shard_rows = -(-self.max_batch // self.num_shards)   # Bs
        self._shard_slots = [
            np.arange(w, self.max_batch, self.num_shards, dtype=np.int64)
            for w in range(self.num_shards)]
        # which worker currently serves each batch slot (the engine rebinds
        # this at admission; defaults to the slot-modulo shard layout) —
        # scoped refreshes cover the shards of every slot a covered worker
        # serves, so non-slot routings (stream affinity) stay sound
        self._slot_worker = np.arange(self.max_batch,
                                      dtype=np.int64) % self.num_shards

    def _pad_shard_rows(self, rows: np.ndarray) -> np.ndarray:
        """Pad a shard's real rows to the uniform (Bs, M) slice shape
        (ragged tail rows stay -1 and are never addressed)."""
        if len(rows) == self.shard_rows:
            return rows
        padded = np.full((self.shard_rows, self.max_blocks_per_seq), -1,
                         np.int32)
        padded[:len(rows)] = rows
        return padded

    def _stack_from_host(self) -> jax.Array:
        """Fresh (W, Bs, M) device stack from the host mirror (construction
        and reshard only — steady-state updates are per-shard slices)."""
        stack = np.stack([
            self._pad_shard_rows(self._host_tables[self._shard_slots[w]])
            for w in range(self.num_shards)])
        return jnp.asarray(stack, jnp.int32)

    def _on_fence_issued(self, evt: FenceIssued) -> None:
        if self._in_reshard and evt.reason == "reshard":
            # the repartition that triggered this fence already uploaded
            # authoritative tables for every moved row (counted under the
            # device.reshard_* counters) — re-uploading here would bill
            # the topology change twice
            return
        self._device_fence(evt.reason, evt.n_blocks, evt.workers)

    def _handle_swap_dropped(self, evt: SwapDropped) -> None:
        """Mapping destroyed with this block swapped out — free the copy."""
        self._swap_store.pop((evt.mapping_id, evt.logical_idx), None)

    def _swap_out(self, mid: int, idx: int, phys: int) -> None:
        self._swap_store[(mid, idx)] = {
            key: np.asarray(self.state[key][:, phys])
            for key in self._pool_keys}

    def _swap_in(self, mid: int, idx: int, phys: int) -> None:
        data = self._swap_store.pop((mid, idx), None)
        if data is None:
            return
        for key, rows in data.items():
            self.state[key] = self.state[key].at[:, phys].set(
                jnp.asarray(rows))

    # -------------------------------------------------- measured fence cost
    def bind_slot_worker(self, slot: int, worker: int) -> None:
        """Record which worker serves ``slot`` (engine routing update)."""
        self._slot_worker[slot] = int(worker) % self.num_shards

    def _shards_of(self, workers) -> list[int]:
        """Worker ids → device-table shard indices to refresh.

        Covers the workers' own shards plus the shard of every batch slot
        currently bound to a covered worker — under non-slot routing a
        worker's rows can live outside its modulo shard, and those rows are
        exactly what its in-flight dispatches captured.
        """
        covered = {int(w) % self.num_shards for w in workers}
        shards = set(covered)
        bound = np.nonzero(np.isin(self._slot_worker,
                                   np.asarray(sorted(covered))))[0]
        shards.update(int(s) % self.num_shards for s in bound)
        return sorted(shards)

    def _live_row(self, slot: int) -> np.ndarray:
        """Authoritative table row for ``slot`` from live mapping state."""
        row = np.full(self.max_blocks_per_seq, -1, np.int32)
        m = self._slot_mappings.get(int(slot))
        if m is not None and m.mapping_id in self.mgr.tables.mappings:
            self._fill_row(row, m)
        return row

    def _device_fence(self, reason: str, n_blocks: int,
                      workers=None) -> None:
        """Drain in-flight steps + re-upload table shards (shootdown cost).

        A global fence (``workers is None``) re-uploads *every* shard — the
        paper's broadcast pessimism.  A scoped fence re-uploads only the
        shards of the workers it covered; everyone else's device copy stays
        valid (their shard epoch did not move), so refreshed bytes scale
        with the fence's mask popcount instead of the worker count.
        """
        jax.block_until_ready(self.state["tables"])      # the drain
        shards = (range(self.num_shards) if workers is None
                  else self._shards_of(workers))
        # Per-island replica groups: under a multi-island topology a
        # *scoped* fence splits its shard set — shards inside the covered
        # islands re-upload in full, shards pulled in from remote islands
        # (foreign-slot bindings under non-slot routing) receive a
        # delta-propagated update instead: the same authoritative rows
        # land on the device (token identity), but the transfer is the
        # compact remote-shootdown delta, billed to device.island.* and
        # excluded from refreshed_bytes.
        topo = self.mgr.topology
        remote: set = set()
        if topo is not None and workers is not None:
            cov_isl = set(topo.islands_of(int(w) % self.num_shards
                                          for w in workers))
            remote = {int(w) for w in shards
                      if topo.island_of(int(w)) not in cov_isl}
        # Authoritative post-fence rows: re-derive from the mappings that
        # are still live in the manager (a fence can fire mid-step — after
        # an alloc/evict/free but before the next update_tables — so the
        # last-uploaded mirror lags reality).  Only the covered shards'
        # slots are rebuilt: host-side fence work scales with the mask
        # popcount, like the upload it feeds.
        entries = nbytes = 0
        d_entries = d_bytes = 0
        tables = self.state["tables"]
        for w in shards:
            slots = self._shard_slots[w]
            rows = np.stack([self._live_row(s) for s in slots]) \
                if len(slots) else np.zeros((0, self.max_blocks_per_seq),
                                            np.int32)
            if int(w) in remote:
                # delta propagation: only the rows that differ from the
                # remote replica's current copy travel the interconnect
                diff = int((rows != self._host_tables[slots]).sum()) \
                    if len(slots) else 0
                d_entries += diff
                d_bytes += diff * rows.itemsize
            else:
                entries += rows.size
                nbytes += rows.nbytes
            self._host_tables[slots] = rows              # device now has them
            tables = tables.at[w].set(
                jnp.asarray(self._pad_shard_rows(rows), jnp.int32))
        self.state["tables"] = tables
        self._refreshed_entries += entries
        self._refreshed_bytes += nbytes
        if topo is not None and workers is not None:
            if self._island_device is None:
                self._island_device = {"intra_refreshes": 0,
                                       "remote_deltas": 0,
                                       "delta_entries": 0,
                                       "delta_bytes": 0}
            st = self._island_device
            st["intra_refreshes"] += len(shards) - len(remote)
            st["remote_deltas"] += len(remote)
            st["delta_entries"] += d_entries
            st["delta_bytes"] += d_bytes
        self._fence_drains += 1
        if workers is None:
            self._full_refreshes += 1
        else:
            self._shard_refreshes += 1
        if self.bus.wants(ShardRefreshed):
            self.bus.publish(ShardRefreshed(
                reason=reason, shards=tuple(int(s) for s in shards),
                entries=entries + d_entries, nbytes=nbytes + d_bytes,
                full=workers is None))

    # ------------------------------------------------------------- reshard
    @property
    def topology(self):
        """The installed multi-island topology, ``None`` when flat."""
        return self.mgr.topology

    def reshard(self, new_num_workers: int, translation=None) -> dict:
        """Elastic topology change on a *live* cache (drain-free for every
        row that does not move shards).

        Delegates the host-side remap (masks, epochs, slots, free lists,
        ledgered overflow records) to :meth:`FprMemoryManager.reshard`;
        the cache's own work happens in the :class:`TopologyChanged`
        subscriber, which runs *before* the manager's scoped reshard fence
        so the fence's epoch bump lands on the new layout.  Returns the
        manager's reshard plan.
        """
        return self._reshape_impl(new_num_workers, translation, None)

    def reshape(self, topology, translation=None) -> dict:
        """Elastic *hierarchical* topology change: reshard onto the
        topology's worker count AND install its island partition in the
        same sync point (islands join/leave live).  A flat spec is exactly
        :meth:`reshard`."""
        from repro.core.topology import Topology
        topo = Topology.of(topology)
        # the topology is passed explicitly even when flat — reshape
        # semantics are "install THIS partition", so a flat spec clears a
        # previously multi-island layout (reshard's None keeps whatever
        # survives the count change instead)
        return self._reshape_impl(topo.num_workers, translation, topo)

    def _reshape_impl(self, new_num_workers: int, translation,
                      topology) -> dict:
        if translation is None:
            translation = self.mgr.default_translation(new_num_workers)
        jax.block_until_ready(self.state["tables"])      # topology sync point
        # the cache's slot space is the decode batch, distinct from the
        # store's table-slot space: the (translated) old owners losing
        # *live batch rows* join the manager's single reshard fence
        alive = self.mgr.tables.mappings
        extra = {int(translation[s % self.num_shards])
                 for s in self._moved_batch_slots(new_num_workers,
                                                  translation)
                 if (m := self._slot_mappings.get(int(s))) is not None
                 and m.mapping_id in alive}
        self._in_reshard = True
        try:
            plan = self.mgr.reshard(new_num_workers, translation,
                                    extra_fence_workers=sorted(extra),
                                    topology=topology)
        finally:
            self._in_reshard = False
        return plan

    def _moved_batch_slots(self, new_num_workers: int,
                           translation) -> list[int]:
        """Batch slots whose device-shard owner changes under the reshard
        (``translation[s % W_old] != s % W_new``)."""
        old_w = self.num_shards
        return [s for s in range(self.max_batch)
                if int(translation[s % old_w]) != s % new_num_workers]

    def _on_topology_changed(self, evt: TopologyChanged) -> None:
        """Repartition the device shard stack onto the new worker set.

        Only the *moved* slots' rows are re-derived from live mapping
        state and counted as reshard refresh traffic — every other row's
        device copy is carried over byte-for-byte (in a real deployment
        the unmoved shards are simply not re-broadcast; here the stack is
        rebuilt from the mirror, which holds exactly what the device
        holds).  Refreshed bytes therefore scale with the moved fraction,
        strictly below one full-table re-upload whenever any row stays.
        """
        W = evt.new_num_workers
        trans = evt.translation
        # moved rows in the cache's own slot space (the decode batch) —
        # the event's moved_slots are store-table slots, a different space
        moved = self._moved_batch_slots(W, trans)
        old_slot_worker = self._slot_worker
        self._init_shard_layout(W)
        # carry engine routing through the translation (the engine rebinds
        # per its own policy right after resize_workers)
        self._slot_worker = np.asarray(
            [trans[int(w)] if int(w) < len(trans) else int(w) % W
             for w in old_slot_worker], dtype=np.int64) % W
        self.num_workers = W
        for s in moved:                      # authoritative data for movers
            self._host_tables[s] = self._live_row(s)
        self.state["tables"] = self._stack_from_host()
        row_bytes = self._host_tables[0].nbytes
        self._reshards += 1
        self._reshard_moved_entries += len(moved) * self.max_blocks_per_seq
        self._reshard_refreshed_bytes += len(moved) * row_bytes

    # ------------------------------------------------------- prefix sharing
    @property
    def prefix_sharing(self) -> bool:
        return self.mgr.prefix_sharing

    def prefix_hashes(self, prompt_tokens) -> tuple:
        """Chain hashes of the prompt's full token blocks (empty when
        sharing is off — callers can pass the result straight through)."""
        if not self.prefix_sharing:
            return ()
        return block_hashes(prompt_tokens, self.block_size)

    def probe_prefix(self, hashes) -> int:
        """How many leading blocks a request with these hashes would attach
        to *right now* (the admission governor's unique-block estimate)."""
        if not self.prefix_sharing or not hashes:
            return 0
        return len(self.mgr.prefix.match(hashes))

    def ensure_private(self, m: Mapping, logical_idx: int, *,
                       worker: int = 0) -> bool:
        """Copy-on-write before a divergent write into a shared block.

        If the mapping's block at ``logical_idx`` is shared with other
        live sharers, allocate a private copy, duplicate the KV pool rows
        (old block → new block, the actual copy of copy-on-write), and
        repoint the mapping.  Returns True iff a copy was made.  The old
        block stays inside its sharing set — no fence (see
        :meth:`FprMemoryManager.cow`).
        """
        res = self.mgr.cow(m.mapping_id, logical_idx, worker=worker)
        if res is None:
            return False
        old, new = res
        for key in self._pool_keys:
            self.state[key] = self.state[key].at[:, new].set(
                self.state[key][:, old])
        return True

    # ---------------------------------------------------------- allocation
    def alloc_sequence(self, n_tokens: int, *, stream: str = "default",
                       group_id: int | None = None,
                       use_fpr: bool | None = None,
                       worker: int = 0, prefix_hashes=()) -> Mapping:
        n_blocks = max(1, -(-n_tokens // self.block_size))
        gid = group_id if group_id is not None else 1
        ctx = self.contexts.resolve(
            group_id=gid, stream_name=stream,
            use_fpr=self.fpr_enabled if use_fpr is None else use_fpr)
        return self.mgr.mmap(n_blocks, ctx, worker=worker,
                             prefix_hashes=prefix_hashes)

    def extend_sequence(self, m: Mapping, n_blocks: int = 1, *,
                        worker: int = 0) -> None:
        self.mgr.extend(m.mapping_id, n_blocks, worker=worker)

    def free_sequence(self, m: Mapping, *, worker: int = 0) -> None:
        self.mgr.munmap(m.mapping_id, worker=worker)

    # ------------------------------------------------------- device tensors
    def _fill_row(self, row: np.ndarray, m: Mapping) -> None:
        """Write a mapping's physical blocks into a (pre-cleared) table row."""
        n = min(len(m.physical), self.max_blocks_per_seq)
        row[:n] = [b if b >= 0 else -1 for b in m.physical[:n]]

    def _host_rows(self, mappings: dict[int, Mapping]) -> np.ndarray:
        """Host (max_batch, M) table from slot → mapping."""
        tab = np.full((self.max_batch, self.max_blocks_per_seq), -1,
                      np.int32)
        for slot, m in mappings.items():
            self._fill_row(tab[slot], m)
        return tab

    def slot_tables(self, mappings: dict[int, Mapping]) -> jax.Array:
        """A standalone (max_batch, M) device table (prefill temp views)."""
        return jnp.asarray(self._host_rows(mappings))

    def update_tables(self, mappings: dict[int, Mapping],
                      lengths: np.ndarray) -> None:
        """Per-step table update: upload only the shards whose rows changed
        — each one a single slice update of the stacked device array; the
        kernels consume the stack directly, so nothing is assembled."""
        self._slot_mappings = dict(mappings)
        host = self._host_rows(mappings)
        tables = self.state["tables"]
        for w, slots in enumerate(self._shard_slots):
            rows = host[slots]
            if not np.array_equal(rows, self._host_tables[slots]):
                tables = tables.at[w].set(
                    jnp.asarray(self._pad_shard_rows(rows), jnp.int32))
                self._step_upload_entries += rows.size
        self._host_tables = host
        self.state["tables"] = tables
        self.state["lengths"] = jnp.asarray(lengths, jnp.int32)

    def _device_metrics(self) -> dict:
        d = {"fence_drains": self._fence_drains,
             "table_shards": self.num_shards,
             "full_refreshes": self._full_refreshes,
             "shard_refreshes": self._shard_refreshes,
             "refreshed_entries": self._refreshed_entries,
             "refreshed_bytes": self._refreshed_bytes,
             "reshards": self._reshards,
             "reshard_moved_entries": self._reshard_moved_entries,
             "reshard_refreshed_bytes": self._reshard_refreshed_bytes,
             "step_upload_entries": self._step_upload_entries}
        if self._island_device is not None:
            d["island"] = dict(self._island_device)
        return d
