"""Continuous-batching scheduler with FPR-aware block lifecycle.

Requests flow  queued → prefill → decoding → done.  Completion frees the
sequence's blocks (the munmap analogue — with FPR the fence is skipped and
the blocks recycle to the next admitted request of the same stream), and
admission allocates them back (the allocation-phase check).

**Admission is the allocation phase.**  The paper moves the shootdown
check from release to allocation (§IV-A); in the serving stack the
matching boundary is admission: which queued request inherits the freed
blocks decides whether the allocation-phase check finds its *own*
context's blocks (a fence-free ``recycled_hit``) or a foreign context's (a
context-exit fence).  The scheduler itself stays mechanism-only — it
moves requests between queue and slots; *policy* (capacity checks,
admission order, victim choice) lives in
:mod:`repro.serving.admission` and is driven by the engine.  Legacy
``admit()`` (no governor) fills every free slot regardless of pool
capacity, which is what over-commits the pool on tight configurations.

**Preemption is the kswapd analogue.**  Under memory pressure a victim
loses its slot and re-queues at the front.  :meth:`preempt` either frees
the victim's mapping (recompute strategy: blocks recycle fence-free under
FPR, the sequence re-prefills on re-admission) or keeps mapping and
generated tokens intact (swap strategy: the caller has already pushed the
blocks out through the watermark evictor, and the demand pager faults
them back in after re-admission).  Either way the victim's blocks leave
the running set — a preempted mapping is never silently leaked.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.core.block_table import Mapping


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int
    stream: str = "default"
    group_id: int = 1
    priority: int = 0                  # admission class (higher = sooner)
    arrival: int = 0                   # submission ordinal (deterministic
                                       # virtual arrival time)
    sla: Optional[float] = None        # deadline budget for SLA-aware
                                       # admission (deadline = arrival+sla)
    prefix_hashes: tuple = ()          # chain hashes of the prompt's full
                                       # blocks (computed once at submit;
                                       # admission probes + alloc reuse it)
    # runtime
    slot: Optional[int] = None
    mapping: Optional[Mapping] = None
    generated: list = field(default_factory=list)
    state: str = "queued"              # queued|prefill|running|done
    preemptions: int = 0
    prefill_pos: int = 0               # prompt tokens already prefilled
                                       # (chunked prefill state machine)
    submit_step: int = 0               # engine step at (re-)enqueue — the
                                       # queue-wait histogram's clock zero
                                       # (reset on preemption re-queue)

    @property
    def length(self) -> int:
        return len(self.prompt) + len(self.generated)


class Scheduler:
    """Slot-based continuous batching over a fixed decode batch."""

    def __init__(self, max_batch: int):
        self.max_batch = max_batch
        self.queue: list[Request] = []
        self.running: dict[int, Request] = {}      # slot → request
        self.done: list[Request] = []
        self._rid = itertools.count(1)

    def submit(self, prompt, max_new_tokens: int, stream: str = "default",
               group_id: int = 1, priority: int = 0,
               sla: Optional[float] = None,
               prefix_hashes: tuple = ()) -> int:
        rid = next(self._rid)
        self.queue.append(Request(rid=rid,
                                  prompt=np.asarray(prompt, np.int32),
                                  max_new_tokens=max_new_tokens,
                                  stream=stream, group_id=group_id,
                                  priority=priority, arrival=rid,
                                  sla=sla, prefix_hashes=prefix_hashes))
        return rid

    def admissible(self) -> list[int]:
        return [s for s in range(self.max_batch) if s not in self.running]

    def place(self, r: Request, slot: int, state: str = "running") -> None:
        """Seat an already-dequeued request in a free slot.

        ``state="prefill"`` seats a chunked-prefill request: it occupies a
        slot and its mapping participates in eviction/paging, but decode
        skips it until the engine promotes it to ``"running"`` once every
        prompt chunk is in the cache.
        """
        if slot in self.running:
            raise ValueError(f"slot {slot} already occupied")
        if state not in ("running", "prefill"):
            raise ValueError(f"cannot place a request in state {state!r}")
        r.slot = slot
        r.state = state
        self.running[slot] = r

    def admit(self) -> list[Request]:
        """Legacy admission: fill every free slot in arrival order
        (no capacity check — the governor path replaces this)."""
        admitted = []
        for slot in self.admissible():
            if not self.queue:
                break
            r = self.queue.pop(0)
            self.place(r, slot)
            admitted.append(r)
        return admitted

    def complete(self, r: Request) -> None:
        r.state = "done"
        del self.running[r.slot]
        self.done.append(r)

    def preempt(self, r: Request, *,
                free: Callable[[Mapping], None] | None = None,
                keep_mapping: bool = False) -> None:
        """Victim loses its slot and re-queues at the front.

        ``free`` releases the victim's blocks back to the cache (recompute
        strategy); the mapping is cleared *before* re-queueing so a
        preempted request can never leak blocks.  ``keep_mapping`` is the
        swap strategy: the caller has already swapped the blocks out, so
        mapping and generated tokens survive for fault-back re-admission.
        """
        if not keep_mapping and r.mapping is not None and free is None:
            raise ValueError(
                "preempting a mapped request without a free callback "
                "would leak its blocks; pass free= or keep_mapping=True")
        del self.running[r.slot]
        r.slot = None
        r.state = "queued"
        r.preemptions += 1
        if not keep_mapping:
            if r.mapping is not None:
                free(r.mapping)
                r.mapping = None
            r.generated.clear()        # re-prefill on re-admission
            r.prefill_pos = 0          # chunked prefill restarts from 0
        self.queue.insert(0, r)

    @property
    def idle(self) -> bool:
        return not self.queue and not self.running
