"""Continuous-batching scheduler with FPR-aware block lifecycle.

Requests flow  queued → prefill → decoding → done.  Completion frees the
sequence's blocks (the munmap analogue — with FPR the fence is skipped and
the blocks recycle to the next admitted request of the same stream), and
admission allocates them back (the allocation-phase check).  Preemption
under memory pressure swaps a victim's blocks out through the watermark
evictor and re-queues it (the kswapd analogue).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.block_table import Mapping


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int
    stream: str = "default"
    group_id: int = 1
    # runtime
    slot: Optional[int] = None
    mapping: Optional[Mapping] = None
    generated: list = field(default_factory=list)
    state: str = "queued"              # queued|running|done

    @property
    def length(self) -> int:
        return len(self.prompt) + len(self.generated)


class Scheduler:
    """Slot-based continuous batching over a fixed decode batch."""

    def __init__(self, max_batch: int):
        self.max_batch = max_batch
        self.queue: list[Request] = []
        self.running: dict[int, Request] = {}      # slot → request
        self.done: list[Request] = []
        self._rid = itertools.count(1)

    def submit(self, prompt, max_new_tokens: int, stream: str = "default",
               group_id: int = 1) -> int:
        rid = next(self._rid)
        self.queue.append(Request(rid=rid,
                                  prompt=np.asarray(prompt, np.int32),
                                  max_new_tokens=max_new_tokens,
                                  stream=stream, group_id=group_id))
        return rid

    def admissible(self) -> list[int]:
        return [s for s in range(self.max_batch) if s not in self.running]

    def admit(self) -> list[Request]:
        """Move queued requests into free slots (caller allocates blocks)."""
        admitted = []
        for slot in self.admissible():
            if not self.queue:
                break
            r = self.queue.pop(0)
            r.slot = slot
            r.state = "running"
            self.running[slot] = r
            admitted.append(r)
        return admitted

    def complete(self, r: Request) -> None:
        r.state = "done"
        del self.running[r.slot]
        self.done.append(r)

    def preempt(self, r: Request) -> None:
        """Victim loses its slot and re-queues at the front."""
        del self.running[r.slot]
        r.slot = None
        r.state = "queued"
        r.generated.clear()            # re-prefill on re-admission
        self.queue.insert(0, r)

    @property
    def idle(self) -> bool:
        return not self.queue and not self.running
