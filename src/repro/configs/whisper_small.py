"""whisper-small [audio] — encoder-decoder; conv frontend is a STUB.
[arXiv:2212.04356; unverified]

12L (decoder) d_model=768 12H (kv=12, MHA) d_ff=3072 vocab=51865 (padded to
51968).  12 encoder layers over 1500 precomputed frame embeddings
(``input_specs`` supplies frames; the conv tower is out of scope).  Learned
positions (no rope).  decode shapes exercise the paged self-KV cache +
immutable cross-KV; head_dim = 768/12 = 64.
"""

from repro.models.config import AttnConfig, ModelConfig

VOCAB_RAW = 51865
ENC_LEN = 1500


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=12, d_ff=3072, vocab=51968, head_dim=64,
        enc_dec=True, enc_layers=12, enc_len=ENC_LEN, frontend="audio",
        attn=AttnConfig(rope=False))


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-small-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=256, head_dim=16,
        enc_dec=True, enc_layers=2, enc_len=32, frontend="audio",
        attn=AttnConfig(rope=False))
