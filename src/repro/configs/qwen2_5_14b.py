"""qwen2.5-14b [dense] — GQA with QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf]

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064.
"""

from repro.models.config import AttnConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b", n_layers=48, d_model=5120, n_heads=40,
        n_kv_heads=8, d_ff=13824, vocab=152064, head_dim=128,
        attn=AttnConfig(qkv_bias=True, rope_theta=1_000_000.0))


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=192, vocab=256, head_dim=16,
        attn=AttnConfig(qkv_bias=True))
