"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE every other
layer (16 experts, top-2). [arXiv:2403.19887; hf]

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.  Period-8 blocks:
attention at in-block offset 4 (1 attn : 7 mamba), MoE on odd offsets.
Attention layers carry no positional encoding (Mamba provides order).
"""

from repro.models.config import (AttnConfig, MambaConfig, ModelConfig,
                                 MoEConfig)


def _patterns(n_layers: int):
    mixers = tuple("attn" if i % 8 == 4 else "mamba" for i in range(n_layers))
    ffns = tuple("moe" if i % 2 == 1 else "dense" for i in range(n_layers))
    return mixers, ffns


def config() -> ModelConfig:
    n_layers = 32
    mixers, ffns = _patterns(n_layers)
    return ModelConfig(
        name="jamba-v0.1-52b", n_layers=n_layers, d_model=4096, n_heads=32,
        n_kv_heads=8, d_ff=14336, vocab=65536, head_dim=128,
        mixers=mixers, ffns=ffns,
        moe=MoEConfig(num_experts=16, top_k=2, d_expert=14336),
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
        attn=AttnConfig(rope=False))


def smoke() -> ModelConfig:
    n_layers = 8                       # one full period
    mixers, ffns = _patterns(n_layers)
    return ModelConfig(
        name="jamba-v0.1-52b-smoke", n_layers=n_layers, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=96, vocab=256, head_dim=16,
        mixers=mixers, ffns=ffns,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=96),
        mamba=MambaConfig(d_state=8, d_conv=4, expand=2),
        attn=AttnConfig(rope=False))
