"""rwkv6-7b [ssm] — "Finch", attention-free, data-dependent decay.
[arXiv:2404.05892; hf]

32L d_model=4096 d_ff=14336 vocab=65536.  No KV cache exists: decode state is
a constant-size per-head (64×64) WKV accumulator + token-shift buffer, so KV
paging — and therefore FPR — is inapplicable to this arch (recorded in
DESIGN.md §Arch-applicability).  The arch still runs through the same engine
with a recycled state-pool.
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    n_layers = 32
    return ModelConfig(
        name="rwkv6-7b", n_layers=n_layers, d_model=4096, n_heads=64,
        n_kv_heads=64, d_ff=14336, vocab=65536, head_dim=64,
        mixers=("rwkv6",) * n_layers)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b-smoke", n_layers=2, d_model=128, n_heads=2,
        n_kv_heads=2, d_ff=256, vocab=256, head_dim=64,
        mixers=("rwkv6",) * 2)
