"""deepseek-moe-16b [moe] — fine-grained MoE, 2 shared + 64 routed top-6.
[arXiv:2401.06066; hf]

28L d_model=2048 16H (kv=16) d_expert=1408 vocab=102400; layer 0 is dense
(d_ff=10944), layers 1..27 are MoE — the DeepSeekMoE layout.
"""

from repro.models.config import AttnConfig, ModelConfig, MoEConfig


def config() -> ModelConfig:
    n_layers = 28
    return ModelConfig(
        name="deepseek-moe-16b", n_layers=n_layers, d_model=2048, n_heads=16,
        n_kv_heads=16, d_ff=1408, vocab=102400, head_dim=128,
        mixers=("attn",) * n_layers,
        ffns=("dense",) + ("moe",) * (n_layers - 1),
        dense_d_ff=10944,
        moe=MoEConfig(num_experts=64, top_k=6, num_shared=2, d_expert=1408),
        attn=AttnConfig(rope_theta=10_000.0))


def smoke() -> ModelConfig:
    n_layers = 3
    return ModelConfig(
        name="deepseek-moe-16b-smoke", n_layers=n_layers, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=48, vocab=256, head_dim=16,
        mixers=("attn",) * n_layers, ffns=("dense",) + ("moe",) * 2,
        dense_d_ff=128,
        moe=MoEConfig(num_experts=8, top_k=2, num_shared=1, d_expert=48))
