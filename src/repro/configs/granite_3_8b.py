"""granite-3-8b [dense] — GQA. [hf:ibm-granite/granite-3.0-2b-base; hf]

40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155 (padded to 49408 for
16-way TP divisibility; padding rows carry -inf-free zero logits).
"""

from repro.models.config import AttnConfig, ModelConfig

VOCAB_RAW = 49155


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b", n_layers=40, d_model=4096, n_heads=32,
        n_kv_heads=8, d_ff=12800, vocab=49408, head_dim=128,
        attn=AttnConfig(rope_theta=10_000.0), tie_embeddings=True)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=192, vocab=256, head_dim=16, tie_embeddings=True)
