"""h2o-danube-3-4b [dense] — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; unverified]

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000; SWA window 4096.
head_dim = 3840/32 = 120.  The 4096-token window bounds decode KV reads, so
long_500k runs for this arch (ring-window mask over the paged cache).
"""

from repro.models.config import AttnConfig, ModelConfig

SWA_WINDOW = 4096


def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b", n_layers=24, d_model=3840, n_heads=32,
        n_kv_heads=8, d_ff=10240, vocab=32000, head_dim=120,
        attn=AttnConfig(window=SWA_WINDOW, rope_theta=10_000.0))


def smoke() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=192, vocab=256, head_dim=16,
        attn=AttnConfig(window=32))
