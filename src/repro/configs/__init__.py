"""Architecture registry: one module per assigned architecture.

``get_config(arch_id)`` returns the full-size ModelConfig; ``get_smoke(arch_id)``
returns a reduced same-family config for CPU smoke tests.  ``SHAPES`` defines
the assigned input-shape set shared by every LM arch.
"""

from __future__ import annotations

import importlib
from dataclasses import dataclass

ARCH_IDS = (
    "jamba-v0.1-52b",
    "whisper-small",
    "internvl2-26b",
    "deepseek-v2-236b",
    "deepseek-moe-16b",
    "deepseek-7b",
    "granite-3-8b",
    "h2o-danube-3-4b",
    "qwen2.5-14b",
    "rwkv6-7b",
)

_MODULE_OF = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
              for a in ARCH_IDS}


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

#: Archs allowed to run long_500k (sub-quadratic or bounded-window decode).
#: Pure full-attention archs skip it per the assignment and DESIGN.md.
LONG_CONTEXT_ARCHS = {"jamba-v0.1-52b", "rwkv6-7b", "h2o-danube-3-4b"}


def get_config(arch_id: str):
    mod = importlib.import_module(_MODULE_OF[arch_id])
    return mod.config()


def get_smoke(arch_id: str):
    mod = importlib.import_module(_MODULE_OF[arch_id])
    return mod.smoke()


def cells(multi_pod: bool = False):
    """Yield every (arch, shape) dry-run cell, honouring long_500k skips."""
    for a in ARCH_IDS:
        for s in SHAPES.values():
            if s.name == "long_500k" and a not in LONG_CONTEXT_ARCHS:
                continue
            yield a, s
