"""deepseek-v2-236b [moe] — MLA (kv_lora=512) + 2 shared + 160 routed top-6.
[arXiv:2405.04434; hf]

60L d_model=5120 128H d_expert=1536 vocab=102400.  Layer 0 dense (d_ff=12288),
59 MoE layers.  MLA pages store rank-512 latents + 64-dim rope keys — ~9×
smaller than GQA pages, so they recycle ~9× faster (FPR's best case).
"""

from repro.models.config import (AttnConfig, MLAConfig, ModelConfig,
                                 MoEConfig)


def config() -> ModelConfig:
    n_layers = 60
    return ModelConfig(
        name="deepseek-v2-236b", n_layers=n_layers, d_model=5120,
        n_heads=128, n_kv_heads=128, d_ff=1536, vocab=102400, head_dim=128,
        mixers=("mla",) * n_layers,
        ffns=("dense",) + ("moe",) * (n_layers - 1),
        dense_d_ff=12288,
        moe=MoEConfig(num_experts=160, top_k=6, num_shared=2, d_expert=1536),
        mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
                      nope_head_dim=128, v_head_dim=128),
        attn=AttnConfig(rope_theta=10_000.0))


def smoke() -> ModelConfig:
    n_layers = 3
    return ModelConfig(
        name="deepseek-v2-236b-smoke", n_layers=n_layers, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=48, vocab=256, head_dim=16,
        mixers=("mla",) * n_layers, ffns=("dense",) + ("moe",) * 2,
        dense_d_ff=128,
        moe=MoEConfig(num_experts=8, top_k=2, num_shared=1, d_expert=48),
        mla=MLAConfig(kv_lora_rank=32, q_lora_rank=48, rope_head_dim=8,
                      nope_head_dim=16, v_head_dim=16))
