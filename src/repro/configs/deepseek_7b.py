"""deepseek-7b [dense] — llama-arch MHA. [arXiv:2401.02954; hf]

30L d_model=4096 32H (kv=32) d_ff=11008 vocab=102400.
"""

from repro.models.config import AttnConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b", n_layers=30, d_model=4096, n_heads=32,
        n_kv_heads=32, d_ff=11008, vocab=102400, head_dim=128,
        attn=AttnConfig(rope_theta=10_000.0))


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=160, vocab=256, head_dim=16)
