"""internvl2-26b [vlm] — InternViT frontend (STUB) + InternLM2-20B backbone.
[arXiv:2404.16821; hf]

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553 (padded to 92672).
``input_specs`` provides 256 precomputed ViT patch embeddings per image as a
prefix; the vision tower itself is a stub projection.
"""

from repro.models.config import AttnConfig, ModelConfig

VOCAB_RAW = 92553
PREFIX_TOKENS = 256


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b", n_layers=48, d_model=6144, n_heads=48,
        n_kv_heads=8, d_ff=16384, vocab=92672, head_dim=128,
        frontend="vision", prefix_tokens=PREFIX_TOKENS,
        attn=AttnConfig(rope_theta=1_000_000.0))


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=192, vocab=256, head_dim=16,
        frontend="vision", prefix_tokens=8)
