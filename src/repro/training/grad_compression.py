"""Int8 error-feedback gradient compression for the cross-pod all-reduce.

At 512+ chips the slowest collective hop is the pod-to-pod gradient
reduction (DCN-ish links).  We compress per-leaf to int8 with a per-leaf
absmax scale and carry the quantisation error into the next step
(error feedback keeps convergence unbiased in expectation).

Two entry points:
  * ``compress_tree`` / ``decompress_tree`` — the wire codec + error
    feedback, applied around XLA's implicit all-reduce (the reduction then
    moves 4× fewer bytes; the dry-run collective-bytes term shows it).
  * ``compressed_psum`` — explicit shard_map psum of the int8 payload for
    engines that manage their own collectives.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_leaf(g: jax.Array, err: jax.Array):
    """g+err → (q int8, scale f32 scalar, new_err)."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, gf - deq


def compress_tree(grads, err_state):
    """Returns (quantised tree (int8, scale), new_err_state)."""
    qs, scales, errs = [], [], []
    flat, treedef = jax.tree.flatten(grads)
    for g, e in zip(flat, jax.tree.leaves(err_state)):
        q, s, ne = compress_leaf(g, e)
        qs.append(q)
        scales.append(s)
        errs.append(ne)
    return ((jax.tree.unflatten(treedef, qs),
             jax.tree.unflatten(treedef, scales)),
            jax.tree.unflatten(treedef, errs))


def decompress_tree(qtree, like=None):
    qs, scales = qtree
    out = jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, qs, scales)
    if like is not None:
        out = jax.tree.map(lambda o, l: o.astype(l.dtype), out, like)
    return out


def compressed_psum(grads, err_state, axis: str):
    """shard_map body: int8-compress, widen to int32 for the psum (int8
    accumulate overflows), dequantise with the psum'd scale sum."""
    (qs, scales), new_err = compress_tree(grads, err_state)
    summed = jax.tree.map(
        lambda q: jax.lax.psum(q.astype(jnp.int32), axis), qs)
    # each rank contributed with its own scale; the unbiased combine uses
    # the mean scale (ranks see same-magnitude grads in steady state)
    n = jax.lax.psum(1, axis)
    mean_scale = jax.tree.map(lambda s: jax.lax.psum(s, axis) / n, scales)
    out = jax.tree.map(lambda q, s: q.astype(jnp.float32) * s,
                       summed, mean_scale)
    return out, new_err
