"""AdamW with decoupled weight decay + global-norm clipping (pure JAX).

Moments are f32 regardless of param dtype (bf16 params train stably with
f32 moments at these scales); the update is computed in f32 and cast back.
Optimizer state inherits the parameters' sharding specs (ZeRO-style: the
FSDP axes shard the moments exactly like the weights).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    #: moment dtype: f32 default; bf16 halves optimizer HBM for ≥100B
    #: models (update math stays f32 — only storage narrows)
    moments_dtype: str = "float32"


def init_opt_state(params, moments_dtype: str = "float32") -> dict:
    dt = jnp.dtype(moments_dtype)
    z = lambda p: jnp.zeros(p.shape, dt)
    return {"mu": jax.tree.map(z, params),
            "nu": jax.tree.map(z, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then constant (benchmarks run a few hundred steps)."""
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    return cfg.lr * warm


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = schedule(cfg, state["step"])
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    mdt = jnp.dtype(cfg.moments_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                    # no decay on norms/biases
            step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * step_).astype(p.dtype)
        return newp, m.astype(mdt), v.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["mu"])
    flat_v = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {"mu": jax.tree.unflatten(treedef, [o[1] for o in out]),
                 "nu": jax.tree.unflatten(treedef, [o[2] for o in out]),
                 "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
