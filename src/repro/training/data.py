"""Deterministic, preemption-safe synthetic data pipeline.

Batches are a pure function of (seed, step): restart/elastic-resume lands on
exactly the token stream it would have seen, with no iterator state to
checkpoint and O(1) skip-ahead.  The stream mimics an LM mixture: Zipfian
token ids with document boundaries; labels are next-token with -100 padding
at document tails (exercises the masked-loss path).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    mean_doc_len: int = 512


class SyntheticLM:
    """data[step] → {"tokens": (B,S) int32, "labels": (B,S) int32}."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # Zipf CDF once (host): p(i) ∝ 1/(i+10)
        ranks = np.arange(cfg.vocab, dtype=np.float64) + 10.0
        p = 1.0 / ranks
        self._cdf = np.cumsum(p / p.sum())

    def batch(self, step: int) -> dict:
        c = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed, step]))
        u = rng.random((c.global_batch, c.seq_len))
        toks = np.searchsorted(self._cdf, u).astype(np.int32)
        toks = np.clip(toks, 0, c.vocab - 1)
        # document boundaries: geometric lengths, boundary token 0
        labels = np.roll(toks, -1, axis=1).astype(np.int32)
        labels[:, -1] = -100
        n_bounds = max(1, c.seq_len // c.mean_doc_len)
        cuts = rng.integers(0, c.seq_len, size=(c.global_batch, n_bounds))
        for b in range(c.global_batch):
            labels[b, cuts[b]] = -100
        return {"tokens": toks, "labels": labels}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1
