"""Train step factory + fault-tolerant training driver.

``make_train_step`` builds the jitted (donated, sharded) step:

    (params, opt_state, batch) → (params, opt_state, metrics)

with microbatched gradient accumulation (``lax.scan`` keeps the HLO one
microbatch wide — activation memory is bounded by mb, not the global
batch), optional int8 error-feedback gradient compression on the FSDP
reduction, and remat inherited from the model's scanned blocks.

``train`` is the driver: checkpoint/restart (atomic, elastic), preemption-
safe data skip-ahead, straggler-aware step timing, NaN guard.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shard_rules
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.training import optimizer as opt_mod
from repro.training.grad_compression import (compress_tree, decompress_tree,
                                             init_error_state)


@dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    adamw: opt_mod.AdamWConfig = field(default_factory=opt_mod.AdamWConfig)
    compress_grads: bool = False
    attn_impl: str = "chunked"           # 'chunked' | 'pallas' on TPU
    moe_groups: int = 1
    remat: bool = True
    #: microbatch gradient-accumulation dtype (bf16 halves the accumulator
    #: tree for ≥100B models; f32 default)
    accum_dtype: str = "float32"


def _microbatch(batch: dict, n: int) -> dict:
    return {k: v.reshape((n, v.shape[0] // n) + v.shape[1:])
            for k, v in batch.items()}


def make_train_step(cfg: ModelConfig, tc: TrainConfig, mesh=None,
                    donate: bool = True):
    """Returns (step_fn, make_shardings).  ``step_fn`` is jitted; shardings
    are attached when a mesh is given (None = single-device smoke)."""

    if mesh is not None:
        dp = shard_rules.dp_axes(mesh)
        act_spec = P(dp if len(dp) != 1 else dp[0], None, None)
    else:
        act_spec = None

    def loss_of(params, mb):
        return tfm.loss_fn(params, cfg, mb, impl=tc.attn_impl,
                           moe_groups=tc.moe_groups, act_spec=act_spec,
                           mesh=mesh)

    def step(params, opt_state, err_state, batch):
        if tc.microbatches == 1:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        else:
            mbs = _microbatch(batch, tc.microbatches)

            adt = jnp.dtype(tc.accum_dtype)

            def acc_fn(carry, mb):
                loss_acc, gacc = carry
                l, g = jax.value_and_grad(loss_of)(params, mb)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(adt), gacc, g)
                return (loss_acc + l, gacc), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, adt), params)
            (loss, grads), _ = jax.lax.scan(
                acc_fn, (jnp.zeros((), jnp.float32), zero), mbs)
            inv = 1.0 / tc.microbatches
            loss = loss * inv
            grads = jax.tree.map(lambda g: g * inv, grads)

        if tc.compress_grads:
            qtree, err_state = compress_tree(grads, err_state)
            grads = decompress_tree(qtree, like=grads)

        params, opt_state, metrics = opt_mod.adamw_update(
            params, grads, opt_state, tc.adamw)
        metrics["loss"] = loss
        return params, opt_state, err_state, metrics

    if mesh is None:
        return jax.jit(step, donate_argnums=(0, 1, 2) if donate else ())

    def shardings(params_shape):
        pspec = shard_rules.param_specs(params_shape, mesh)
        psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec)
        osh = {"mu": psh, "nu": psh,
               "step": NamedSharding(mesh, P())}
        esh = psh if tc.compress_grads else None
        bsp = shard_rules.batch_specs(
            mesh, has_patches=cfg.frontend == "vision",
            has_frames=cfg.enc_dec)
        bsh = {k: NamedSharding(mesh, v) for k, v in bsp.items()}
        msh = NamedSharding(mesh, P())
        return psh, osh, esh, bsh, msh

    def jitted(params_shape, batch_keys=("tokens", "labels")):
        psh, osh, esh, bsh, msh = shardings(params_shape)
        bsh = {k: bsh[k] for k in batch_keys}
        return jax.jit(
            step,
            in_shardings=(psh, osh, esh, bsh),
            out_shardings=(psh, osh, esh,
                           {"loss": msh, "grad_norm": msh, "lr": msh}),
            donate_argnums=(0, 1, 2) if donate else ())

    return step, jitted


def init_all(key, cfg: ModelConfig, tc: TrainConfig, dtype=jnp.bfloat16):
    params = tfm.init_params(key, cfg, dtype)
    opt_state = opt_mod.init_opt_state(params)
    err_state = (init_error_state(params) if tc.compress_grads
                 else jnp.zeros((), jnp.float32))
    return params, opt_state, err_state


def train(cfg: ModelConfig, tc: TrainConfig, data_iter, *, steps: int,
          ckpt_mgr=None, ckpt_every: int = 100, mesh=None,
          seed: int = 0, log_every: int = 10, dtype=jnp.bfloat16,
          params=None, opt_state=None) -> dict:
    """Driver with checkpoint/restart.  Returns final metrics history."""
    key = jax.random.PRNGKey(seed)
    if params is None:
        params, opt_state, err_state = init_all(key, cfg, tc, dtype)
    else:
        err_state = (init_error_state(params) if tc.compress_grads
                     else jnp.zeros((), jnp.float32))

    start = 0
    if ckpt_mgr is not None and ckpt_mgr.latest_step() is not None:
        start = ckpt_mgr.latest_step()
        state = ckpt_mgr.restore(start, {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]

    if mesh is None:
        step_fn = make_train_step(cfg, tc, None)
    else:
        _, jitted = make_train_step(cfg, tc, mesh)
        step_fn = jitted(jax.eval_shape(lambda: params))

    hist = {"loss": [], "step_time": []}
    for s in range(start, steps):
        batch = {k: jnp.asarray(v) for k, v in data_iter.batch(s).items()}
        t0 = time.perf_counter()
        params, opt_state, err_state, metrics = step_fn(
            params, opt_state, err_state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        if not jnp.isfinite(loss):
            raise FloatingPointError(f"non-finite loss at step {s}: {loss}")
        hist["loss"].append(loss)
        hist["step_time"].append(dt)
        if log_every and s % log_every == 0:
            print(f"step {s:5d}  loss {loss:8.4f}  "
                  f"gnorm {float(metrics['grad_norm']):7.3f}  {dt*1e3:7.1f}ms")
        if ckpt_mgr is not None and (s + 1) % ckpt_every == 0:
            ckpt_mgr.save(s + 1, {"params": params, "opt": opt_state},
                          blocking=False)
    if ckpt_mgr is not None:
        ckpt_mgr.wait()
    return hist
