"""Atomic, sharded, elastic checkpoint manager.

* **Atomic**: a checkpoint is staged in ``step_<n>.tmp`` and ``os.replace``d
  into place — a crash mid-save never corrupts the latest checkpoint.
* **Sharded**: every leaf is saved as per-device-shard entries with global
  indices (distributed/elastic.py), the on-disk analogue of a real
  multi-host checkpoint (each host writes only what it owns).
* **Elastic**: restore reassembles leaves by index math and re-shards onto
  *any* mesh — resume 512→256 chips after losing a pod, or back up.
* **Async**: ``save(..., blocking=False)`` snapshots to host then writes on
  a background thread; training continues immediately.
* **Retention**: keeps the newest ``keep`` checkpoints, deletes the rest.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

from repro.distributed.elastic import assemble, reshard, shard_entries

_SEP = "/"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        flat[key] = leaf
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, blocking: bool = True) -> str:
        flat = _flatten(tree)
        # snapshot shards to host *now* (donated buffers may die after)
        manifest = {"step": step, "leaves": {}}
        payload: dict[str, np.ndarray] = {}
        for key, leaf in flat.items():
            arr = leaf if isinstance(leaf, jax.Array) else jax.numpy.asarray(leaf)
            entries = list(shard_entries(arr))
            manifest["leaves"][key] = {
                "shape": list(arr.shape), "dtype": str(arr.dtype),
                "shards": [list(map(list, idx)) for idx, _ in entries]}
            for i, (_, data) in enumerate(entries):
                payload[f"{key}::{i}"] = data

        def _write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "shards.npz"), **payload)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)             # atomic publish
            self._gc()

        if blocking:
            _write()
        else:
            self.wait()
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        return os.path.join(self.dir, f"step_{step}")

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore_host(self, step: int) -> tuple[dict, int]:
        """Load flat {path: np.ndarray} for a step (mesh-agnostic)."""
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        z = np.load(os.path.join(d, "shards.npz"))
        flat = {}
        for key, meta in manifest["leaves"].items():
            entries = [(tuple(map(tuple, idx)), z[f"{key}::{i}"])
                       for i, idx in enumerate(meta["shards"])]
            flat[key] = assemble(tuple(meta["shape"]),
                                 np.dtype(meta["dtype"]), entries)
        return flat, manifest["step"]

    def restore(self, step: int, like_tree, mesh=None, specs=None):
        """Restore into the structure of ``like_tree``; optionally reshard
        onto a (possibly different) mesh."""
        flat, _ = self.restore_host(step)
        like_flat = _flatten(like_tree)
        missing = set(like_flat) - set(flat)
        if missing:
            raise KeyError(f"checkpoint missing leaves: {sorted(missing)[:5]}")
        leaves = [flat[k] for k in like_flat]
        treedef = jax.tree.structure(like_tree)
        tree = jax.tree.unflatten(treedef, leaves)
        if mesh is not None and specs is not None:
            tree = reshard(tree, mesh, specs)
        return tree
