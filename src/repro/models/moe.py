"""Mixture-of-Experts FFN — DeepSeek-style shared + routed top-k experts.

Dispatch is the sort-based, capacity-bounded scheme (no one-hot combine
tensors, which would be O(tokens × E × C) and explode at 1M tokens):

  1. top-k routing per token, gates renormalised over the chosen k,
  2. flatten (token, k) slots and sort by expert id,
  3. position-in-expert from segment arithmetic (no one-hot),
  4. scatter-add kept slots into an (E, C, D) buffer (dropped slots add 0),
  5. batched expert SwiGLU: einsum over the expert dim (EP-sharded),
  6. gather back through the inverse permutation, weight by gates, sum over k.

Sharding: expert weights are (E, D, d_e) with E over the ``model`` axis; the
(G, E, C, D) dispatch buffer is sharded G over data and E over model so GSPMD
lowers the scatter/gather into an all-to-all style exchange.  Grouping (G) is
chosen per data shard so the sorts stay shard-local.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_dense, rms_norm


def init_moe(key, cfg, dtype=jnp.bfloat16) -> dict:
    mo = cfg.moe
    D = cfg.d_model
    de = mo.d_expert or cfg.d_ff
    E = mo.num_experts
    ks = jax.random.split(key, 7)
    p = {
        "norm": jnp.ones((D,), dtype),
        "router": init_dense(ks[0], D, E, jnp.float32),   # routing in f32
        "w_gate": (jax.random.normal(ks[1], (E, D, de), jnp.float32)
                   / jnp.sqrt(D)).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, D, de), jnp.float32)
                 / jnp.sqrt(D)).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, de, D), jnp.float32)
                   / jnp.sqrt(de)).astype(dtype),
    }
    if mo.num_shared:
        ds = de * mo.num_shared
        p["s_gate"] = init_dense(ks[4], D, ds, dtype)
        p["s_up"] = init_dense(ks[5], D, ds, dtype)
        p["s_down"] = init_dense(ks[6], ds, D, dtype)
    return p


def capacity_for(tokens_per_group: int, top_k: int, num_experts: int,
                 capacity_factor: float = 1.25, min_capacity: int = 4) -> int:
    c = int(tokens_per_group * top_k * capacity_factor / num_experts) + 1
    # round up to a multiple of 4 for lane friendliness
    c = max(min_capacity, (c + 3) & ~3)
    return c


def _route(router: jax.Array, x: jax.Array, top_k: int):
    """x: (T, D) → gates (T,k) f32, experts (T,k) i32, aux-loss scalar."""
    logits = x.astype(jnp.float32) @ router          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, top_k)        # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux: E · Σ_e f_e · P_e
    E = router.shape[1]
    me = probs.mean(axis=0)                                     # (E,)
    # fraction of routed slots per expert, without a (T,E) one-hot:
    ce = jnp.zeros((E,), jnp.float32).at[eidx.reshape(-1)].add(
        1.0 / (eidx.size))
    aux = E * jnp.sum(me * ce)
    return gates, eidx, aux


def _ep_constrain(t, ep_axis):
    """Pin the expert dim to the EP mesh axis — without it GSPMD keeps
    the (E, C, d_e) dispatch buffers fully replicated per chip (tens of
    GB for Jamba/DeepSeek prefill)."""
    if ep_axis is None:
        return t
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        t, P(ep_axis, *([None] * (t.ndim - 1))))


def _dispatch_compute(x: jax.Array, gates: jax.Array, eidx: jax.Array,
                      w_gate, w_up, w_down, capacity: int,
                      ep_axis: str | None = None):
    """x: (T,D); gates/eidx: (T,k). Returns (T,D) routed-expert output."""
    T, D = x.shape
    k = eidx.shape[1]
    E = w_gate.shape[0]
    eflat = eidx.reshape(-1)                           # (T·k,)
    order = jnp.argsort(eflat)                         # stable
    sorted_e = eflat[order]
    counts = jnp.bincount(eflat, length=E)             # (E,)
    seg_start = jnp.cumsum(counts) - counts            # (E,)
    pos_in_e = jnp.arange(T * k) - seg_start[sorted_e]
    keep = pos_in_e < capacity
    dest = sorted_e * capacity + jnp.where(keep, pos_in_e, 0)
    token_of = order // k                              # source token per slot
    contrib = jnp.where(keep[:, None], x[token_of], 0)
    buf = jnp.zeros((E * capacity, D), x.dtype).at[dest].add(contrib)
    buf = _ep_constrain(buf.reshape(E, capacity, D), ep_axis)
    # batched expert SwiGLU (EP: E sharded over the model axis)
    h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate))
         * jnp.einsum("ecd,edf->ecf", buf, w_up))
    h = _ep_constrain(h, ep_axis)
    out = _ep_constrain(jnp.einsum("ecf,efd->ecd", h, w_down), ep_axis)
    out = out.reshape(E * capacity, D)
    # gather back, zero dropped slots, unsort, gate-weight
    slot_out = jnp.where(keep[:, None], out[dest], 0)  # (T·k, D)
    inv = jnp.argsort(order)
    slot_out = slot_out[inv].reshape(T, k, D)
    return jnp.einsum("tkd,tk->td", slot_out.astype(jnp.float32),
                      gates).astype(x.dtype)


def moe_mix(params: dict, x: jax.Array, cfg, *, num_groups: int = 1,
            capacity_factor: float = 1.25, ep_axis: str | None = None,
            dp_axis=None):
    """MoE FFN body on pre-normed x: (B,S,D) → (out, aux_loss).

    ``dp_axis`` shards the group dim of the vmapped dispatch over the
    data axes (spmd_axis_name), ``ep_axis`` pins expert-dim sharding —
    together they keep every dispatch buffer (G/|dp|, E/|ep|, C, ·)
    shard-local."""
    mo = cfg.moe
    B, S, D = x.shape
    T = B * S
    xf = x.reshape(T, D)
    # group so sorts stay shard-local; groups must divide tokens
    G = num_groups
    while T % G:
        G -= 1
    xg = xf.reshape(G, T // G, D)
    cap = capacity_for(T // G, mo.top_k, mo.num_experts, capacity_factor)

    def per_group(xt):
        gates, eidx, aux = _route(params["router"], xt, mo.top_k)
        out = _dispatch_compute(xt, gates, eidx, params["w_gate"],
                                params["w_up"], params["w_down"], cap,
                                ep_axis=ep_axis)
        return out, aux

    outs, auxs = jax.vmap(per_group,
                          spmd_axis_name=dp_axis if G > 1 else None)(xg)
    out = outs.reshape(B, S, D)
    if mo.num_shared:
        sh = (jax.nn.silu(xf @ params["s_gate"]) * (xf @ params["s_up"])
              ) @ params["s_down"]
        out = out + sh.reshape(B, S, D)
    return out, auxs.mean() * mo.router_aux_coef


def moe_ffn(params: dict, x: jax.Array, cfg, *, num_groups: int = 1,
            ep_axis: str | None = None, dp_axis=None):
    """Pre-norm residual MoE block: (B,S,D) → (x+out, aux)."""
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    out, aux = moe_mix(params, h, cfg, num_groups=num_groups,
                       ep_axis=ep_axis, dp_axis=dp_axis)
    return x + out, aux
