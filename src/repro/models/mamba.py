"""Mamba (S6 selective scan) mixer — Jamba's 7-in-8 layer.

Train/prefill: chunked scan — sequential ``lax.scan`` over sequence chunks
with an associative scan inside each chunk (bounded memory; mirrors the
kernels/mamba_scan Pallas kernel's VMEM chunking).
Decode: O(1) recurrent step carrying (conv_state, ssm_state).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_dense, rms_norm


def dt_rank(cfg) -> int:
    return max(16, cfg.d_model // 16)


def init_mamba(key, cfg, dtype=jnp.bfloat16) -> dict:
    mm = cfg.mamba
    D, DI = cfg.d_model, cfg.d_inner
    R = dt_rank(cfg)
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, mm.d_state + 1, dtype=jnp.float32)[None, :],
                 (DI, 1))
    return {
        "norm": jnp.ones((D,), dtype),
        "in_proj": init_dense(ks[0], D, 2 * DI, dtype),
        "conv_w": (jax.random.normal(ks[1], (mm.d_conv, DI), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((DI,), dtype),
        "x_proj": init_dense(ks[2], DI, R + 2 * mm.d_state, dtype),
        "dt_proj": init_dense(ks[3], R, DI, dtype),
        "A_log": jnp.log(A),                      # f32: recurrence stability
        "D": jnp.ones((DI,), jnp.float32),
        "out_proj": init_dense(ks[4], DI, D, dtype),
    }


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv1d. u: (B,S,DI), w: (K,DI). Returns (y, new_state)
    where state carries the last K−1 inputs for decode continuity."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], K - 1, u.shape[2]), u.dtype)
    else:
        pad = state.astype(u.dtype)
    ext = jnp.concatenate([pad, u], axis=1)          # (B, S+K-1, DI)
    y = sum(ext[:, i:i + u.shape[1], :] * w[i] for i in range(K)) + b
    new_state = ext[:, -(K - 1):, :]
    return y, new_state


def _ssm_chunk_scan(dA: jax.Array, dBu: jax.Array, h0: jax.Array):
    """h_t = dA_t * h_{t-1} + dBu_t over axis 1 (chunk), given h0.

    dA, dBu: (B, T, DI, N) f32.  Associative scan within the chunk.
    Returns (h_all (B,T,DI,N), h_last).
    """
    def combine(a, b):
        (a1, b1), (a2, b2) = a, b
        return a1 * a2, a2 * b1 + b2

    aa, bb = jax.lax.associative_scan(combine, (dA, dBu), axis=1)
    h_all = aa * h0[:, None] + bb
    return h_all, h_all[:, -1]


def mamba_mix(params: dict, u: jax.Array, cfg, *, chunk: int = 64,
              conv_state=None, ssm_state=None, impl: str = "chunked"):
    """Core mixer on pre-normed input u: (B,S,D) → (y, (conv_state, ssm_state)).

    impl='chunked' (default) | 'sequential' (oracle) | 'pallas'/'pallas_interpret'.
    """
    mm = cfg.mamba
    B, S, D = u.shape
    DI, N = cfg.d_inner, mm.d_state
    R = dt_rank(cfg)
    xz = u @ params["in_proj"]
    x, z = jnp.split(xz, 2, axis=-1)
    x, conv_state = _causal_conv(x, params["conv_w"], params["conv_b"],
                                 conv_state)
    x = jax.nn.silu(x)
    proj = x @ params["x_proj"]
    delta, Bc, Cc = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus((delta @ params["dt_proj"]).astype(jnp.float32))
    A = -jnp.exp(params["A_log"])                   # (DI,N)
    xf = x.astype(jnp.float32)
    Bf = Bc.astype(jnp.float32)
    Cf = Cc.astype(jnp.float32)

    h0 = (jnp.zeros((B, DI, N), jnp.float32) if ssm_state is None
          else ssm_state)

    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels.mamba_scan import ops as ms_ops
        y_ssm, h_last = ms_ops.mamba_scan(dt, A, Bf, Cf, xf, h0,
                                          interpret=(impl == "pallas_interpret"))
    elif impl == "sequential":
        def step(h, t):
            dA = jnp.exp(dt[:, t, :, None] * A[None])
            h = dA * h + (dt[:, t, :, None] * Bf[:, t, None, :]
                          * xf[:, t, :, None])
            y = jnp.einsum("bdn,bn->bd", h, Cf[:, t])
            return h, y
        h_last, ys = jax.lax.scan(step, h0, jnp.arange(S))
        y_ssm = ys.transpose(1, 0, 2)
    else:                                            # chunked
        pad = (-S) % chunk
        dtp = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bp = jnp.pad(Bf, ((0, 0), (0, pad), (0, 0)))
        Cp = jnp.pad(Cf, ((0, 0), (0, pad), (0, 0)))
        xp = jnp.pad(xf, ((0, 0), (0, pad), (0, 0)))
        T = dtp.shape[1]
        nck = T // chunk
        dtc = dtp.reshape(B, nck, chunk, DI).transpose(1, 0, 2, 3)
        Bcc = Bp.reshape(B, nck, chunk, N).transpose(1, 0, 2, 3)
        Ccc = Cp.reshape(B, nck, chunk, N).transpose(1, 0, 2, 3)
        xcc = xp.reshape(B, nck, chunk, DI).transpose(1, 0, 2, 3)

        @jax.checkpoint
        def chunk_step(h, inp):
            # checkpointed: the backward recomputes the (B,T,DI,N) dA/dBu/
            # h_all tensors per chunk instead of scan-stacking them (they
            # dominate training memory for Jamba's 28 mamba layers)
            dtk, Bk, Ck, xk = inp
            dA = jnp.exp(dtk[..., None] * A[None, None])       # (B,T,DI,N)
            dBu = dtk[..., None] * Bk[:, :, None, :] * xk[..., None]
            h_all, h_last = _ssm_chunk_scan(dA, dBu, h)
            y = jnp.einsum("btdn,btn->btd", h_all, Ck)
            return h_last, y

        h_last, ys = jax.lax.scan(chunk_step, h0, (dtc, Bcc, Ccc, xcc))
        y_ssm = ys.transpose(1, 0, 2, 3).reshape(B, T, DI)[:, :S]

    y = y_ssm + params["D"][None, None] * xf
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(u.dtype)
    return y @ params["out_proj"], (conv_state, h_last)


def mamba_layer(params: dict, x: jax.Array, cfg, *, impl="chunked") -> jax.Array:
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    y, _ = mamba_mix(params, h, cfg, impl=impl)
    return x + y


def mamba_decode_step(params: dict, x: jax.Array, cfg, conv_state, ssm_state):
    """x: (B,D) single token → (y (B,D), new states). O(1) per step."""
    h = rms_norm(x[:, None], params["norm"], cfg.norm_eps)
    y, (cs, ss) = mamba_mix(params, h, cfg, conv_state=conv_state,
                            ssm_state=ssm_state, impl="sequential")
    return x + y[:, 0], (cs, ss)


def init_mamba_state(cfg, batch: int):
    mm = cfg.mamba
    return (jnp.zeros((batch, mm.d_conv - 1, cfg.d_inner), jnp.bfloat16),
            jnp.zeros((batch, cfg.d_inner, mm.d_state), jnp.float32))
