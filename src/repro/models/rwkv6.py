"""RWKV-6 "Finch" time-mix — attention-free mixer with data-dependent decay.

State per head is a (head_dim × head_dim) outer-product accumulator:

    S_t = diag(w_t) · S_{t-1} + k_tᵀ v_t          (w_t data-dependent, <1)
    y_t = r_t · (S_{t-1} + diag(u) k_tᵀ v_t)

Train/prefill uses a chunked scan (sequential over chunks, intra-chunk
unrolled matmuls — mirrors kernels/rwkv6_scan); decode is the O(1) recurrence.
FPR note: no KV cache exists — the framework runs this arch with a recycled
state-pool only (DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_dense, rms_norm

HEAD_SIZE = 64


def init_rwkv6(key, cfg, dtype=jnp.bfloat16) -> dict:
    D = cfg.d_model
    nH = D // HEAD_SIZE
    ks = jax.random.split(key, 8)
    return {
        "norm": jnp.ones((D,), dtype),
        "mu": (jax.random.uniform(ks[0], (5, D), jnp.float32)).astype(dtype),
        "w_lora_a": init_dense(ks[1], D, 64, dtype),
        "w_lora_b": (jax.random.normal(ks[2], (64, D), jnp.float32) * 0.01
                     ).astype(dtype),
        "wr": init_dense(ks[3], D, D, dtype),
        "wk": init_dense(ks[4], D, D, dtype),
        "wv": init_dense(ks[5], D, D, dtype),
        "wg": init_dense(ks[6], D, D, dtype),
        "u": (jax.random.normal(ks[7], (nH, HEAD_SIZE), jnp.float32) * 0.1
              ).astype(jnp.float32),
        "ln_x": jnp.stack([jnp.ones((D,), jnp.float32),
                           jnp.zeros((D,), jnp.float32)]),
        "wo": init_dense(jax.random.fold_in(key, 99), D, D, dtype),
    }


def _projections(params, x, x_prev, cfg):
    """Token-shift mixing + r/k/v/g/w projections. x,x_prev: (B,S,D)."""
    mu = params["mu"].astype(jnp.float32)
    xf, xp = x.astype(jnp.float32), x_prev.astype(jnp.float32)
    mix = lambda i: (xf + mu[i] * (xp - xf)).astype(x.dtype)
    r = mix(0) @ params["wr"]
    k = mix(1) @ params["wk"]
    v = mix(2) @ params["wv"]
    g = mix(3) @ params["wg"]
    # Finch: data-dependent per-channel decay via LoRA
    w_raw = jnp.tanh(mix(4) @ params["w_lora_a"]) @ params["w_lora_b"]
    w = jnp.exp(-jnp.exp(-0.5 + w_raw.astype(jnp.float32)))   # (0,1)
    return r, k, v, g, w


def _heads(t, nH):
    B, S, D = t.shape
    return t.reshape(B, S, nH, HEAD_SIZE)


def _wkv_sequential(r, k, v, w, u, S0):
    """Oracle recurrence. r,k,v,w: (B,S,nH,hd) f32; S0: (B,nH,hd,hd)."""
    def step(S, t):
        rt, kt, vt, wt = r[:, t], k[:, t], v[:, t], w[:, t]
        kv = kt[..., :, None] * vt[..., None, :]          # (B,nH,hd,hd)
        out = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, out
    S_last, ys = jax.lax.scan(step, S0, jnp.arange(r.shape[1]))
    return ys.transpose(1, 0, 2, 3), S_last


def _wkv_chunked(r, k, v, w, u, S0, chunk=32):
    """Chunked WKV: cross-chunk state carry + intra-chunk direct form."""
    B, S, nH, hd = r.shape
    pad = (-S) % chunk
    if pad:
        z = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
    T = r.shape[1]
    nck = T // chunk
    rc = r.reshape(B, nck, chunk, nH, hd).transpose(1, 0, 2, 3, 4)
    kc = k.reshape(B, nck, chunk, nH, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nck, chunk, nH, hd).transpose(1, 0, 2, 3, 4)
    wc = w.reshape(B, nck, chunk, nH, hd).transpose(1, 0, 2, 3, 4)

    @jax.checkpoint
    def chunk_step(S, inp):
        # checkpointed: backward recomputes the (B,c,c,nH,hd) pairwise
        # decay tensor per chunk rather than stacking it across the scan
        rk, kk, vk, wk_ = inp                              # (B,c,nH,hd)
        # decay products: W_t = prod_{s<=t} w_s within the chunk
        logw = jnp.log(wk_)
        cum = jnp.cumsum(logw, axis=1)                     # inclusive
        Wincl = jnp.exp(cum)                               # (B,c,nH,hd)
        Wexcl = jnp.exp(cum - logw)                        # exclusive
        # contribution of the carried state: r_t · diag(Wexcl_t) S
        y_state = jnp.einsum("bchk,bhkv->bchv", rk * Wexcl, S)
        # intra-chunk: y_t += sum_{s<t} r_t (prod_{s<u<=t-1} w) k_s v_s
        #            + r_t diag(u) k_t v_t
        # pairwise decay D[t,s] = Wexcl_t / Wincl_s  (valid for s < t)
        ratio = Wexcl[:, :, None] / Wincl[:, None, :]      # (B,t,s,nH,hd)
        tidx = jnp.arange(rk.shape[1])
        mask = (tidx[:, None] > tidx[None, :])[None, :, :, None, None]
        att = jnp.einsum("bthk,btshk,bshk->btsh", rk, ratio * mask, kk)
        diag = jnp.einsum("bthk,hk,bthk->bth", rk, u, kk)
        y_intra = (jnp.einsum("btsh,bshv->bthv", att, vk)
                   + diag[..., None] * vk)
        # carry: S' = diag(Wincl_last) S + sum_s (prod_{s<u<=last} w) k_s v_s
        tail = Wincl[:, -1:, :, :] / Wincl                 # (B,c,nH,hd)
        S_new = (Wincl[:, -1][..., None] * S
                 + jnp.einsum("bshk,bshv->bhkv", tail * kk, vk))
        return S_new, y_state + y_intra

    S_last, ys = jax.lax.scan(chunk_step, S0, (rc, kc, vc, wc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, T, nH, hd)[:, :S]
    return y, S_last


def rwkv6_mix(params, u_in, cfg, *, x_prev=None, wkv_state=None,
              impl="chunked"):
    """Pre-normed input u_in: (B,S,D) → (y, (last_x, wkv_state))."""
    B, S, D = u_in.shape
    nH = D // HEAD_SIZE
    if x_prev is None:
        x_prev_full = jnp.concatenate(
            [jnp.zeros((B, 1, D), u_in.dtype), u_in[:, :-1]], axis=1)
    else:
        x_prev_full = jnp.concatenate([x_prev[:, None], u_in[:, :-1]], axis=1)
    r, k, v, g, w = _projections(params, u_in, x_prev_full, cfg)
    rh = _heads(r, nH).astype(jnp.float32)
    kh = _heads(k, nH).astype(jnp.float32)
    vh = _heads(v, nH).astype(jnp.float32)
    wh = _heads(w.astype(jnp.bfloat16), nH).astype(jnp.float32)
    S0 = (jnp.zeros((B, nH, HEAD_SIZE, HEAD_SIZE), jnp.float32)
          if wkv_state is None else wkv_state)
    uu = params["u"]
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels.rwkv6_scan import ops as rk_ops
        y, S_last = rk_ops.rwkv6_scan(rh, kh, vh, wh, uu, S0,
                                      interpret=(impl == "pallas_interpret"))
    elif impl == "sequential":
        y, S_last = _wkv_sequential(rh, kh, vh, wh, uu, S0)
    else:
        y, S_last = _wkv_chunked(rh, kh, vh, wh, uu, S0)
    y = y.reshape(B, S, D)
    # per-head group norm
    scale, bias = params["ln_x"][0], params["ln_x"][1]
    yh = y.reshape(B, S, nH, HEAD_SIZE)
    mean = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    y = ((yh - mean) * jax.lax.rsqrt(var + 1e-5)).reshape(B, S, D)
    y = y * scale + bias
    y = (y * jax.nn.silu(g.astype(jnp.float32)))
    out = y.astype(u_in.dtype) @ params["wo"]
    return out, (u_in[:, -1], S_last)


def rwkv6_layer(params, x, cfg, *, impl="chunked"):
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    y, _ = rwkv6_mix(params, h, cfg, impl=impl)
    return x + y


def rwkv6_decode_step(params, x, cfg, last_x, wkv_state):
    """x: (B,D) → (y, (last_x, wkv_state))."""
    h = rms_norm(x[:, None], params["norm"], cfg.norm_eps)
    y, (lx, st) = rwkv6_mix(params, h, cfg, x_prev=last_x,
                            wkv_state=wkv_state, impl="sequential")
    return x + y[:, 0], (lx, st)


def init_rwkv6_state(cfg, batch: int):
    nH = cfg.d_model // HEAD_SIZE
    return (jnp.zeros((batch, cfg.d_model), jnp.bfloat16),
            jnp.zeros((batch, nH, HEAD_SIZE, HEAD_SIZE), jnp.float32))
