"""ModelConfig — one declarative description drives all ten architectures.

A model is a stack of layers; each layer has a *mixer* (attn | mla | mamba |
rwkv6) and an *ffn* (dense | moe).  Layers are grouped into at most two
chunks for compilation: an optional irregular **prefix** (unrolled) and a
**periodic body** scanned with ``jax.lax.scan`` — e.g. Jamba's period-8
attn/mamba interleave scans 4 blocks of 8 sublayers; DeepSeek-V2's dense
first layer is the prefix and the 59 MoE layers scan.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int                 # routed experts
    top_k: int
    num_shared: int = 0              # DeepSeek shared experts (always on)
    d_expert: int = 0                # per-expert FFN width
    router_aux_coef: float = 0.001   # load-balance aux loss


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2


@dataclass(frozen=True)
class AttnConfig:
    qkv_bias: bool = False           # qwen2.5
    window: int | None = None        # sliding-window (danube)
    rope_theta: float = 10_000.0
    rope: bool = True                # whisper uses learned positions instead


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    # per-layer patterns; callables of layer index would not hash — store tuples
    mixers: tuple[str, ...] = ()     # len n_layers; 'attn'|'mla'|'mamba'|'rwkv6'
    ffns: tuple[str, ...] = ()       # len n_layers; 'dense'|'moe'
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    mamba: MambaConfig | None = None
    attn: AttnConfig = field(default_factory=AttnConfig)
    # encoder-decoder (whisper): encoder is a dense-attn stack of enc_layers
    enc_dec: bool = False
    enc_layers: int = 0
    enc_len: int = 0                 # fixed encoder sequence (1500 frames)
    frontend: str | None = None      # 'audio' | 'vision' (STUB embeddings)
    prefix_tokens: int = 0           # vision prefix length (internvl)
    dense_d_ff: int | None = None    # d_ff of dense layers when mixed w/ MoE
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    max_position: int = 1 << 20

    def __post_init__(self):
        if not self.mixers:
            object.__setattr__(self, "mixers", ("attn",) * self.n_layers)
        if not self.ffns:
            object.__setattr__(self, "ffns", ("dense",) * self.n_layers)
        assert len(self.mixers) == self.n_layers
        assert len(self.ffns) == self.n_layers

    # ----------------------------------------------------------- layer schema
    def layer_sig(self, i: int) -> tuple[str, str]:
        return (self.mixers[i], self.ffns[i])

    def segmentation(self) -> tuple[int, int]:
        """Return (prefix_len, period): layers[:prefix] unroll, the rest scan
        in blocks of ``period`` sublayers."""
        sigs = [self.layer_sig(i) for i in range(self.n_layers)]
        for prefix in range(0, min(4, self.n_layers) + 1):
            body = sigs[prefix:]
            if not body:
                continue
            for period in range(1, min(8, len(body)) + 1):
                if len(body) % period:
                    continue
                if all(body[i] == body[i % period] for i in range(len(body))):
                    return prefix, period
        return self.n_layers, 0          # fully unrolled (shouldn't happen)

    # --------------------------------------------------------------- sizing
    @property
    def d_inner(self) -> int:        # mamba inner width
        return (self.mamba.expand if self.mamba else 2) * self.d_model

    def param_count(self) -> int:
        """Exact parameter count (used for 6·N·D roofline bookkeeping)."""
        return sum(t[1] for t in iter_param_shapes(self))

    def active_param_count(self) -> int:
        """Active params per token (MoE counts top_k + shared experts)."""
        total = 0
        for name, n, active in iter_param_shapes(self, with_active=True):
            total += active
        return total

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


def _prod(shape) -> int:
    n = 1
    for s in shape:
        n *= s
    return n


def iter_param_shapes(cfg: ModelConfig, with_active: bool = False):
    """Yield (name, param_count[, active_count]) without allocating arrays.

    Mirrors models/transformer.py::init_params exactly (asserted in tests).
    """
    out = []

    def add(name, shape, active=None):
        n = _prod(shape)
        out.append((name, n, n if active is None else active))

    D, H, KV, HD, FF, V = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                           cfg.head_dim, cfg.d_ff, cfg.vocab)
    add("embed", (V, D))
    if not cfg.tie_embeddings:
        add("unembed", (V, D))
    add("final_norm", (D,))
    if cfg.frontend == "vision":
        add("vision_proj", (D, D))
    if cfg.frontend == "audio":
        add("audio_proj", (D, D))

    def add_mixer(i, kind):
        p = f"layer{i}.{kind}"
        add(p + ".norm", (D,))
        if kind == "attn":
            add(p + ".wq", (D, H * HD))
            add(p + ".wk", (D, KV * HD))
            add(p + ".wv", (D, KV * HD))
            add(p + ".wo", (H * HD, D))
            if cfg.attn.qkv_bias:
                add(p + ".bq", (H * HD,))
                add(p + ".bk", (KV * HD,))
                add(p + ".bv", (KV * HD,))
        elif kind == "mla":
            m = cfg.mla
            qk_hd = m.nope_head_dim + m.rope_head_dim
            add(p + ".wq_a", (D, m.q_lora_rank))
            add(p + ".q_norm", (m.q_lora_rank,))
            add(p + ".wq_b", (m.q_lora_rank, H * qk_hd))
            add(p + ".wkv_a", (D, m.kv_lora_rank + m.rope_head_dim))
            add(p + ".kv_norm", (m.kv_lora_rank,))
            add(p + ".wkv_b", (m.kv_lora_rank,
                               H * (m.nope_head_dim + m.v_head_dim)))
            add(p + ".wo", (H * m.v_head_dim, D))
        elif kind == "mamba":
            mm = cfg.mamba
            DI = cfg.d_inner
            add(p + ".in_proj", (D, 2 * DI))
            add(p + ".conv_w", (mm.d_conv, DI))
            add(p + ".conv_b", (DI,))
            dt_rank = max(16, D // 16)
            add(p + ".x_proj", (DI, dt_rank + 2 * mm.d_state))
            add(p + ".dt_proj", (dt_rank, DI))
            add(p + ".A_log", (DI, mm.d_state))
            add(p + ".D", (DI,))
            add(p + ".out_proj", (DI, D))
        elif kind == "rwkv6":
            nH = D // 64
            hd = 64
            add(p + ".mu", (5, D))           # token-shift mixes (r,k,v,w,g)
            add(p + ".w_lora_a", (D, 64))
            add(p + ".w_lora_b", (64, D))
            add(p + ".wr", (D, D))
            add(p + ".wk", (D, D))
            add(p + ".wv", (D, D))
            add(p + ".wg", (D, D))
            add(p + ".u", (nH, hd))          # bonus
            add(p + ".ln_x", (2, D))         # per-head groupnorm scale/bias
            add(p + ".wo", (D, D))
        else:  # pragma: no cover
            raise ValueError(kind)

    def add_ffn(i, kind):
        p = f"layer{i}.{kind}"
        add(p + ".norm", (D,))
        if kind == "dense":
            dff = cfg.dense_d_ff or FF
            add(p + ".w_gate", (D, dff))
            add(p + ".w_up", (D, dff))
            add(p + ".w_down", (dff, D))
        else:
            mo = cfg.moe
            de = mo.d_expert or FF
            E = mo.num_experts
            add(p + ".router", (D, E))
            act_frac = mo.top_k / E
            for wname, shape in (("w_gate", (E, D, de)), ("w_up", (E, D, de)),
                                 ("w_down", (E, de, D))):
                add(p + "." + wname, shape, active=int(_prod(shape) * act_frac))
            if mo.num_shared:
                ds = de * mo.num_shared
                add(p + ".s_gate", (D, ds))
                add(p + ".s_up", (D, ds))
                add(p + ".s_down", (ds, D))

    for i in range(cfg.n_layers):
        mix, ffn = cfg.layer_sig(i)
        add_mixer(i, mix)
        add_ffn(i, ffn)

    if cfg.enc_dec:
        for i in range(cfg.enc_layers):
            add_mixer(f"enc{i}", "attn")
            add_ffn(f"enc{i}", "dense")
        for i in range(cfg.n_layers):       # cross-attention per decoder layer
            p = f"layer{i}.cross"
            add(p + ".norm", (D,))
            add(p + ".wq", (D, H * HD))
            add(p + ".wk", (D, KV * HD))
            add(p + ".wv", (D, KV * HD))
            add(p + ".wo", (H * HD, D))
        add("enc_pos", (cfg.enc_len, D))
        add("dec_pos", (4096, D))

    if with_active:
        return out
    return [(n, c) for n, c, _ in out]
