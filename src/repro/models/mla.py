"""Multi-head Latent Attention (DeepSeek-V2) — expanded and absorbed forms.

Train/prefill uses the *expanded* form (regular attention after up-projection).
Decode uses the *absorbed* form: queries are folded through W_UK so attention
runs directly against the rank-512 compressed latent cache — the TPU-friendly
form (dense latent matmuls, no 128-head KV materialisation), and the reason
MLA pages are ~9× smaller than GQA pages (more recycling per second — FPR's
best case, see DESIGN.md §4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import NEG_INF, chunked_attention
from repro.models.layers import apply_rope, init_dense, rms_norm


def init_mla(key, cfg, dtype=jnp.bfloat16) -> dict:
    m = cfg.mla
    D, H = cfg.d_model, cfg.n_heads
    qk_hd = m.nope_head_dim + m.rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "norm": jnp.ones((D,), dtype),
        "wq_a": init_dense(ks[0], D, m.q_lora_rank, dtype),
        "q_norm": jnp.ones((m.q_lora_rank,), dtype),
        "wq_b": init_dense(ks[1], m.q_lora_rank, H * qk_hd, dtype),
        "wkv_a": init_dense(ks[2], D, m.kv_lora_rank + m.rope_head_dim, dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "wkv_b": init_dense(ks[3], m.kv_lora_rank,
                            H * (m.nope_head_dim + m.v_head_dim), dtype),
        "wo": init_dense(ks[4], H * m.v_head_dim, D, dtype),
    }


def _project_q(params, h, cfg, positions):
    m = cfg.mla
    B, S, _ = h.shape
    H = cfg.n_heads
    qk_hd = m.nope_head_dim + m.rope_head_dim
    q = rms_norm(h @ params["wq_a"], params["q_norm"], cfg.norm_eps)
    q = (q @ params["wq_b"]).reshape(B, S, H, qk_hd)
    q_nope, q_rope = jnp.split(q, [m.nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.attn.rope_theta)
    return q_nope, q_rope


def latent_kv(params, h, cfg, positions):
    """Compressed latents: c_kv (B,S,rank), k_rope (B,S,1,rope_hd) — this is
    exactly what the paged cache stores per token."""
    m = cfg.mla
    ckv = h @ params["wkv_a"]
    c_kv, k_rope = jnp.split(ckv, [m.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, params["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.attn.rope_theta)
    return c_kv, k_rope


def mla_layer(params: dict, x: jax.Array, positions: jax.Array, cfg, *,
              impl: str = "chunked") -> jax.Array:
    """Expanded-form MLA for train/prefill (regular GQA-style attention)."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    q_nope, q_rope = _project_q(params, h, cfg, positions)
    c_kv, k_rope = latent_kv(params, h, cfg, positions)
    kv = (c_kv @ params["wkv_b"]).reshape(B, S, H,
                                          m.nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(kv, [m.nope_head_dim], axis=-1)
    # assemble per-head q/k with shared rope key broadcast across heads
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, m.rope_head_dim))],
        axis=-1)
    # pad v to qk head_dim so one attention kernel serves both (cheap: zeros)
    o = chunked_attention(q, k, jnp.pad(
        v, ((0, 0), (0, 0), (0, 0), (0, m.nope_head_dim + m.rope_head_dim
                                     - m.v_head_dim))), causal=True)
    o = o[..., :m.v_head_dim].reshape(B, S, H * m.v_head_dim)
    return x + o @ params["wo"]


def absorbed_weights(params, cfg):
    """Split wkv_b into per-head W_UK (rank→nope) and W_UV (rank→v)."""
    m = cfg.mla
    H = cfg.n_heads
    w = params["wkv_b"].reshape(m.kv_lora_rank, H,
                                m.nope_head_dim + m.v_head_dim)
    w_uk = w[..., :m.nope_head_dim]         # (rank, H, nope)
    w_uv = w[..., m.nope_head_dim:]         # (rank, H, v)
    return w_uk, w_uv


def mla_decode_ref(params: dict, x: jax.Array, positions: jax.Array,
                   c_pool: jax.Array, rope_pool: jax.Array,
                   block_tables: jax.Array, lengths: jax.Array, cfg
                   ) -> jax.Array:
    """Absorbed-form decode over the paged latent cache (jnp reference).

    x:          (B, D)        current-token activations (pre-norm applied here)
    c_pool:     (N, bs, rank) latent pages
    rope_pool:  (N, bs, rope_hd)
    """
    m = cfg.mla
    B, D = x.shape
    H = cfg.n_heads
    h = rms_norm(x[:, None, :], params["norm"], cfg.norm_eps)
    q_nope, q_rope = _project_q(params, h, cfg, positions[:, None])
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]           # (B,H,·)
    w_uk, w_uv = absorbed_weights(params, cfg)
    # absorb: q_lat (B,H,rank) = q_nope · W_UK^T
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    N, bs, rank = c_pool.shape
    M = block_tables.shape[1]
    tables = jnp.maximum(block_tables, 0)
    c = jnp.take(c_pool, tables, axis=0).reshape(B, M * bs, rank)
    kr = jnp.take(rope_pool, tables, axis=0).reshape(B, M * bs,
                                                     m.rope_head_dim)
    scale = 1.0 / jnp.sqrt(m.nope_head_dim + m.rope_head_dim)
    s = (jnp.einsum("bhr,bsr->bhs", q_lat, c.astype(jnp.float32))
         + jnp.einsum("bhr,bsr->bhs", q_rope.astype(jnp.float32),
                      kr.astype(jnp.float32))) * scale
    pos = jnp.arange(M * bs)[None, :]
    valid = (pos < lengths[:, None]) & (jnp.repeat(block_tables, bs, axis=1) >= 0)
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", p, c.astype(jnp.float32))  # latent ctx
    o = jnp.einsum("bhr,rhv->bhv", ctx, w_uv.astype(jnp.float32))
    o = o.reshape(B, H * m.v_head_dim).astype(x.dtype)
    return x + o @ params["wo"]
