"""Attention: GQA with optional sliding window / QKV bias.

Three implementations of the same math (and tests assert they agree):

  * ``direct_attention``   — O(S²) softmax oracle (small shapes only)
  * ``chunked_attention``  — online-softmax over KV chunks in pure jnp:
                             memory-bounded; used for CPU runs and dry-run
                             lowering (cost_analysis sees the true FLOPs)
  * kernels/flash_attention — the Pallas TPU kernel (same math, VMEM tiles)

Decode over the FPR paged KV cache has a jnp reference here
(``paged_decode_attention_ref``) and a Pallas kernel in kernels/paged_attention.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, init_dense, rms_norm

NEG_INF = -1e30


def init_attn(key, cfg, dtype=jnp.bfloat16) -> dict:
    D, H, KV, HD = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 5)
    p = {"norm": jnp.ones((D,), dtype),
         "wq": init_dense(ks[0], D, H * HD, dtype),
         "wk": init_dense(ks[1], D, KV * HD, dtype),
         "wv": init_dense(ks[2], D, KV * HD, dtype),
         "wo": init_dense(ks[3], H * HD, D, dtype)}
    if cfg.attn.qkv_bias:
        p["bq"] = jnp.zeros((H * HD,), dtype)
        p["bk"] = jnp.zeros((KV * HD,), dtype)
        p["bv"] = jnp.zeros((KV * HD,), dtype)
    return p


def qkv_proj(params: dict, x: jax.Array, cfg, positions: jax.Array | None
             ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B,S,D) → q (B,S,H,hd), k/v (B,S,KV,hd), rope applied."""
    B, S, _ = x.shape
    H, KV, HD = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.attn.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, S, H, HD)
    k = k.reshape(B, S, KV, HD)
    v = v.reshape(B, S, KV, HD)
    if cfg.attn.rope and positions is not None:
        q = apply_rope(q, positions, cfg.attn.rope_theta)
        k = apply_rope(k, positions, cfg.attn.rope_theta)
    return q, k, v


# ----------------------------------------------------------------- oracle ----
def direct_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     causal: bool = True, window: int | None = None,
                     q_offset: int = 0) -> jax.Array:
    """q: (B,Sq,H,hd); k,v: (B,Sk,KV,hd). GQA by head grouping."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    q = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k) / jnp.sqrt(hd)
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, Sq, H, hd)


# ----------------------------------------------------- chunked (flash-jnp) ----
def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: int | None = None,
                      q_offset: int = 0, chunk: int = 256) -> jax.Array:
    """Online-softmax attention over KV chunks with a flash-style custom
    backward: the VJP recomputes per-chunk scores from (q, k, v, out, lse)
    instead of letting scan stack every chunk's probability tensor —
    O(S·chunk) live memory in both directions (the naive scan backward
    materialises O(S²/chunk · chunk) = O(S²) residuals; see EXPERIMENTS.md
    §Perf iteration 1)."""
    return _chunked_attention_vjp(q, k, v, causal, window, q_offset, chunk)


def chunked_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                          causal: bool = True, window: int | None = None,
                          q_offset=0, chunk: int = 256) -> jax.Array:
    """Inference-only chunked attention: same online softmax as
    :func:`chunked_attention`, but without the custom VJP wrapper — whose
    ``nondiff_argnums`` pin ``q_offset`` as a static (trace-time) value.
    Here ``q_offset`` may be a traced scalar, which is what lets a
    fixed-shape prefill *chunk* compile once and slide along the sequence
    (see ``transformer.prefill_chunk``)."""
    out, _ = _chunked_fwd(q, k, v, causal, window, q_offset, chunk)
    return out


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _chunked_attention_vjp(q, k, v, causal, window, q_offset, chunk):
    out, _ = _chunked_fwd(q, k, v, causal, window, q_offset, chunk)
    return out


def _chunked_fwd_res(q, k, v, causal, window, q_offset, chunk):
    out, lse = _chunked_fwd(q, k, v, causal, window, q_offset, chunk)
    return out, (q, k, v, out, lse)


#: queries are processed in blocks of this many rows so the live
#: (B, KV, G, q_block, chunk) score tile stays bounded — 128-head MLA at
#: 32k tokens would otherwise materialise ~8 GB score tensors per chunk
Q_BLOCK = 2048


def _q_blocks(Sq: int) -> int:
    return Q_BLOCK if (Sq > Q_BLOCK and Sq % Q_BLOCK == 0) else Sq


def _chunk_mask(qpos, kpos, Sk, causal, window):
    mask = kpos[None, :] < Sk
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    return mask


def _chunked_bwd(causal, window, q_offset, chunk, res, dout):
    q, k, v, out, lse = res
    B, Sq, H, hd = q.shape
    qc = _q_blocks(Sq)
    if qc != Sq:
        nq = Sq // qc
        KVh = lse.shape[1]
        G = lse.shape[2]
        qb = q.reshape(B, nq, qc, H, hd).transpose(1, 0, 2, 3, 4)
        ob = out.reshape(B, nq, qc, H, hd).transpose(1, 0, 2, 3, 4)
        dob = dout.reshape(B, nq, qc, H, hd).transpose(1, 0, 2, 3, 4)
        lseb = lse.reshape(B, KVh, G, nq, qc).transpose(3, 0, 1, 2, 4)

        def step(carry, inp):
            dka, dva = carry
            i, (qi, oi, li, doi) = inp
            dqi, dki, dvi = _chunked_bwd_body(
                causal, window, q_offset, chunk, (qi, k, v, oi, li), doi,
                q_base=i * qc)
            return (dka + dki.astype(jnp.float32),
                    dva + dvi.astype(jnp.float32)), dqi

        zk = jnp.zeros(k.shape, jnp.float32)
        zv = jnp.zeros(v.shape, jnp.float32)
        (dk, dv), dqs = jax.lax.scan(
            step, (zk, zv), (jnp.arange(nq), (qb, ob, lseb, dob)))
        dq = dqs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)
        return dq, dk.astype(k.dtype), dv.astype(v.dtype)
    return _chunked_bwd_body(causal, window, q_offset, chunk, res, dout)


def _chunked_bwd_body(causal, window, q_offset, chunk, res, dout,
                      q_base=0):
    q, k, v, out, lse = res
    B, Sq, H, hd = q.shape
    q_offset = q_offset + q_base
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = hd ** -0.5
    pad = (-Sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = k.shape[1] // chunk
    qf = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32)
    dof = dout.reshape(B, Sq, KV, G, hd).astype(jnp.float32)
    of = out.reshape(B, Sq, KV, G, hd).astype(jnp.float32)
    kc = k.reshape(B, n_chunks, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    qpos = jnp.arange(Sq) + q_offset
    # D_i = Σ_d dO_i·O_i  — the softmax-backward diagonal term
    delta = (dof * of).sum(-1)                         # (B,Sq,KV,G)

    def step(dq, inp):
        ci, (kb, vb) = inp
        kb = kb.astype(jnp.float32)
        vb = vb.astype(jnp.float32)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kb) * scale
        kpos = ci * chunk + jnp.arange(chunk)
        mask = _chunk_mask(qpos, kpos, Sk, causal, window)
        # p from saved lse (no renormalisation pass needed)
        p = jnp.where(mask, jnp.exp(s - lse[..., None]), 0.0)
        dp = jnp.einsum("bqkgd,bskd->bkgqs", dof, vb)
        ds = p * (dp - delta.transpose(0, 2, 3, 1)[..., None]) * scale
        dq = dq + jnp.einsum("bkgqs,bskd->bqkgd", ds, kb)
        dk = jnp.einsum("bkgqs,bqkgd->bskd", ds, qf)
        dv = jnp.einsum("bkgqs,bqkgd->bskd", p, dof)
        return dq, (dk, dv)

    dq0 = jnp.zeros((B, Sq, KV, G, hd), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(step, dq0,
                                  (jnp.arange(n_chunks), (kc, vc)))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, n_chunks * chunk, KV, hd)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, n_chunks * chunk, KV, hd)
    return (dq.reshape(B, Sq, H, hd).astype(q.dtype),
            dk[:, :Sk].astype(k.dtype), dv[:, :Sk].astype(v.dtype))


_chunked_attention_vjp.defvjp(_chunked_fwd_res, _chunked_bwd)


def _chunked_fwd(q: jax.Array, k: jax.Array, v: jax.Array, causal,
                 window, q_offset, chunk):
    """Forward online-softmax over KV chunks → (out, lse); queries are
    processed in Q_BLOCK-row blocks (bounded score tiles)."""
    B, Sq, H, hd = q.shape
    qc = _q_blocks(Sq)
    if qc != Sq:
        nq = Sq // qc
        qb = q.reshape(B, nq, qc, H, hd).transpose(1, 0, 2, 3, 4)

        def one(inp):
            i, qi = inp
            return _chunked_fwd_body(qi, k, v, causal, window,
                                     q_offset + i * qc, chunk)

        outs, lses = jax.lax.map(one, (jnp.arange(nq), qb))
        out = outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)
        KVh, G = lses.shape[2], lses.shape[3]
        lse = lses.transpose(1, 2, 3, 0, 4).reshape(B, KVh, G, Sq)
        return out, lse
    return _chunked_fwd_body(q, k, v, causal, window, q_offset, chunk)


def _chunked_fwd_body(q: jax.Array, k: jax.Array, v: jax.Array, causal,
                      window, q_offset, chunk):
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    if Sk % chunk:
        pad = chunk - Sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = k.shape[1] // chunk
    qf = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32) / jnp.sqrt(hd)
    kc = k.reshape(B, n_chunks, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    qpos = jnp.arange(Sq) + q_offset

    def step(carry, inp):
        m, l, acc = carry
        ci, (kb, vb) = inp
        kb = kb.astype(jnp.float32)
        vb = vb.astype(jnp.float32)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kb)       # (B,KV,G,Sq,chunk)
        kpos = ci * chunk + jnp.arange(chunk)
        mask = kpos[None, :] < Sk
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window is not None:
            mask &= qpos[:, None] - kpos[None, :] < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        scale = jnp.exp(m - m_new)
        l_new = l * scale + p.sum(axis=-1)
        acc_new = acc * scale[..., None] + jnp.einsum("bkgqs,bskd->bkgqd", p, vb)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (jnp.arange(n_chunks), (kc, vc)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)
    lse = m + jnp.log(jnp.maximum(l, 1e-30))           # (B,KV,G,Sq)
    return out.astype(q.dtype), lse


# --------------------------------------------------------- paged decode ref ----
def assemble_shard_tables(tables: jax.Array) -> jax.Array:
    """Monolithic view of a ``(W, Bs, M)`` interleaved shard stack.

    Slot ``b`` lives at shard ``b % W``, local row ``b // W``, so the
    monolithic ``(W*Bs, M)`` table is a pure transpose+reshape — cheap
    inside a traced graph, and the identity for a 2-D table.  Only
    non-shard-native consumers (the jnp reference, sequence-parallel
    collectives, MLA decode) call this; the Pallas kernel indexes the
    stack directly.
    """
    if tables.ndim == 2:
        return tables
    W, Bs, M = tables.shape
    return tables.transpose(1, 0, 2).reshape(W * Bs, M)


def lookup_slot_blocks(tables: jax.Array, slots: jax.Array,
                       blk_idx: jax.Array) -> jax.Array:
    """Physical block of logical block ``blk_idx[i]`` for slot
    ``slots[i]``, for either table layout (monolithic ``(B, M)`` or the
    ``(W, Bs, M)`` shard stack)."""
    if tables.ndim == 2:
        return tables[slots, blk_idx]
    W = tables.shape[0]
    return tables[slots % W, slots // W, blk_idx]


def fuse_kv(k: jax.Array, v: jax.Array) -> jax.Array:
    """Head-interleave K and V along the head axis (K even, V odd).

    ``(..., KV, hd) × 2 → (..., KV*2, hd)`` — the fused-pool layout of
    the paged-attention kernel, where one logical block is ONE
    contiguous DMA instead of two.  Pure permutation: ``split_fused_kv``
    inverts it bit for bit.
    """
    return jnp.stack([k, v], axis=-2).reshape(
        *k.shape[:-2], 2 * k.shape[-2], k.shape[-1])


def split_fused_kv(kv: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Strided K/V views of a head-interleaved fused array (inverse of
    :func:`fuse_kv`)."""
    return kv[..., 0::2, :], kv[..., 1::2, :]


def paged_decode_attention_ref(q: jax.Array, k_pool: jax.Array,
                               v_pool: jax.Array, block_tables: jax.Array,
                               lengths: jax.Array,
                               window: int | None = None) -> jax.Array:
    """Decode attention over the FPR paged cache (jnp reference).

    q:            (B, H, hd)        one new token per sequence
    k_pool/v_pool:(N, bs, KV, hd)   physical block pools
    block_tables: (B, M) int32      logical→physical (−1/−2 = non-resident)
    lengths:      (B,) int32        tokens in cache (incl. the new one)
    window:       sliding-window size (danube SWA); None = full causal
    """
    B, H, hd = q.shape
    N, bs, KV, _ = k_pool.shape
    M = block_tables.shape[1]
    G = H // KV
    tables = jnp.maximum(block_tables, 0)                  # clamp holes
    k = jnp.take(k_pool, tables, axis=0)                   # (B,M,bs,KV,hd)
    v = jnp.take(v_pool, tables, axis=0)
    k = k.reshape(B, M * bs, KV, hd).astype(jnp.float32)
    v = v.reshape(B, M * bs, KV, hd).astype(jnp.float32)
    qf = q.reshape(B, KV, G, hd).astype(jnp.float32) / jnp.sqrt(hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qf, k)               # (B,KV,G,S)
    pos = jnp.arange(M * bs)[None, :]
    valid = (pos < lengths[:, None]) & (
        jnp.repeat(block_tables, bs, axis=1) >= 0)
    if window is not None:
        valid &= pos > lengths[:, None] - 1 - window       # SWA
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v)
    return out.reshape(B, H, hd).astype(q.dtype)


# ------------------------------------------------------------ full layers ----
def attn_layer(params: dict, x: jax.Array, positions: jax.Array, cfg, *,
               impl: str = "chunked") -> jax.Array:
    """Pre-norm residual attention block for train/prefill."""
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    q, k, v = qkv_proj(params, h, cfg, positions)
    w = cfg.attn.window
    if impl == "direct":
        o = direct_attention(q, k, v, causal=True, window=w)
    elif impl == "chunked":
        o = chunked_attention(q, k, v, causal=True, window=w)
    elif impl == "pallas" or impl == "pallas_interpret":
        from repro.kernels.flash_attention import ops as fa_ops
        o = fa_ops.flash_attention(q, k, v, causal=True, window=w,
                                   interpret=(impl == "pallas_interpret"))
    else:
        raise ValueError(impl)
    B, S, H, hd = o.shape
    return x + o.reshape(B, S, H * hd) @ params["wo"]


def cross_attn_layer(params: dict, x: jax.Array, enc_kv: tuple, cfg
                     ) -> jax.Array:
    """Encoder-decoder cross attention (whisper); enc_kv precomputed."""
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    B, S, _ = h.shape
    H, HD = cfg.n_heads, cfg.head_dim
    q = (h @ params["wq"]).reshape(B, S, H, HD)
    k, v = enc_kv
    o = chunked_attention(q, k, v, causal=False)
    return x + o.reshape(B, S, H * HD) @ params["wo"]


def encode_cross_kv(params: dict, enc_out: jax.Array, cfg) -> tuple:
    B, Se, _ = enc_out.shape
    KV, HD = cfg.n_kv_heads, cfg.head_dim
    k = (enc_out @ params["wk"]).reshape(B, Se, KV, HD)
    v = (enc_out @ params["wv"]).reshape(B, Se, KV, HD)
    return k, v
