"""Modality frontends — STUBS per the assignment.

``[audio]`` (whisper) and ``[vlm]`` (internvl2) entries specify the
transformer *backbone* only; ``input_specs()`` provides precomputed
frame/patch embeddings.  The stub here is a single learned projection from
the precomputed embedding space into d_model, so the dry-run sees the
correct input shapes and a realistic (tiny) extra matmul, while the real
conv/ViT tower is explicitly out of scope.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_dense


def init_frontend(key, cfg, dtype=jnp.bfloat16) -> dict:
    if cfg.frontend == "vision":
        return {"vision_proj": init_dense(key, cfg.d_model, cfg.d_model, dtype)}
    if cfg.frontend == "audio":
        return {"audio_proj": init_dense(key, cfg.d_model, cfg.d_model, dtype)}
    return {}


def audio_frames_to_embeddings(params: dict, frames: jax.Array) -> jax.Array:
    """frames: (B, enc_len, d_model) precomputed log-mel+conv embeddings."""
    return frames @ params["audio_proj"]


def vision_patches_to_embeddings(params: dict, patches: jax.Array) -> jax.Array:
    """patches: (B, prefix_tokens, d_model) precomputed ViT patch embeddings."""
    return patches @ params["vision_proj"]
