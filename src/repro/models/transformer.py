"""Model assembly: init, train/prefill forward, chunked loss, decode step.

Layer layout (see ModelConfig.segmentation): an irregular *prefix* is
unrolled; the periodic *body* is scanned with ``jax.lax.scan`` over stacked
parameters (HLO stays small at 512 devices).  Each layer = mixer + ffn
(+ cross-attention for enc-dec).

Decode state is a dict pytree of per-kind cache pools:

  kv:    fused k/v (L_attn, N, bs, KV*2, hd) paged GQA cache, head-
         interleaved (K even, V odd) so one logical block is ONE
         contiguous DMA (writes past the pool scatter-drop)
  mla:   c/rope  (L, N+1, bs, rank|rope_hd)  paged latent cache
  mamba: conv/ssm (L_m, B, K-1, DI) / (L_m, B, DI, dstate)
  rwkv:  last_x/wkv (L, B, D) / (L, B, nH, 64, 64)
  cross: k/v     (L, B, enc_len, KV, hd)     whisper cross-attn (immutable)
  tables (B, M) int32 — FPR block tables; lengths (B,) int32

The decode step is unrolled over layers (small graphs; per-layer pool
indexing is static); train/prefill scan.  Paged attention is pluggable:
``page_impl`` ∈ {'ref' (jnp), 'sp' (shard_map sequence-parallel),
'pallas'/'pallas_interpret' (kernels/paged_attention)}.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.config import ModelConfig
from repro.models.frontends import init_frontend
from repro.models.layers import (embed, init_embed,
                                 init_swiglu, rms_norm, unembed)

BLOCK_SIZE = 128   # tokens per physical KV block (MXU-aligned)


# ============================================================ initialisation
def _init_mixer(key, cfg: ModelConfig, kind: str, dtype):
    if kind == "attn":
        return attn_mod.init_attn(key, cfg, dtype)
    if kind == "mla":
        return mla_mod.init_mla(key, cfg, dtype)
    if kind == "mamba":
        return mamba_mod.init_mamba(key, cfg, dtype)
    if kind == "rwkv6":
        return rwkv_mod.init_rwkv6(key, cfg, dtype)
    raise ValueError(kind)


def _init_ffn(key, cfg: ModelConfig, kind: str, dtype):
    if kind == "dense":
        dff = cfg.dense_d_ff or cfg.d_ff
        p = init_swiglu(key, cfg.d_model, dff, dtype)
        p["norm"] = jnp.ones((cfg.d_model,), dtype)
        return p
    if kind == "moe":
        return moe_mod.init_moe(key, cfg, dtype)
    raise ValueError(kind)


def _init_cross(key, cfg: ModelConfig, dtype):
    D, H, KV, HD = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    from repro.models.layers import init_dense
    return {"norm": jnp.ones((D,), dtype),
            "wq": init_dense(ks[0], D, H * HD, dtype),
            "wk": init_dense(ks[1], D, KV * HD, dtype),
            "wv": init_dense(ks[2], D, KV * HD, dtype),
            "wo": init_dense(ks[3], H * HD, D, dtype)}


def _init_layer(key, cfg: ModelConfig, i: int, dtype):
    mix, ffn = cfg.layer_sig(i)
    k1, k2, k3 = jax.random.split(key, 3)
    lp = {"mix": _init_mixer(k1, cfg, mix, dtype),
          "ffn": _init_ffn(k2, cfg, ffn, dtype)}
    if cfg.enc_dec:
        lp["cross"] = _init_cross(k3, cfg, dtype)
    return lp


def init_params(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    prefix, period = cfg.segmentation()
    keys = jax.random.split(key, cfg.n_layers + 8)
    params: dict[str, Any] = {
        "embed": init_embed(keys[-1], cfg.vocab, cfg.d_model, dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = init_embed(keys[-2], cfg.vocab, cfg.d_model, dtype)
    params.update(init_frontend(keys[-3], cfg, dtype))

    params["prefix"] = tuple(
        _init_layer(keys[i], cfg, i, dtype) for i in range(prefix))
    if period:
        n_blocks = (cfg.n_layers - prefix) // period
        body = []
        for j in range(period):
            per_block = [_init_layer(keys[prefix + b * period + j], cfg,
                                     prefix + b * period + j, dtype)
                         for b in range(n_blocks)]
            body.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_block))
        params["body"] = tuple(body)
    else:
        params["body"] = ()

    if cfg.enc_dec:
        ek = jax.random.split(keys[-4], cfg.enc_layers)
        params["encoder"] = tuple(
            {"mix": attn_mod.init_attn(ek[i], cfg, dtype),
             "ffn": _init_ffn(jax.random.fold_in(ek[i], 7), cfg, "dense",
                              dtype)}
            for i in range(cfg.enc_layers))
        params["enc_pos"] = (jax.random.normal(
            keys[-5], (cfg.enc_len, cfg.d_model), jnp.float32) * 0.02
            ).astype(dtype)
        params["dec_pos"] = (jax.random.normal(
            keys[-6], (4096, cfg.d_model), jnp.float32) * 0.02).astype(dtype)
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ================================================================== forward
def _apply_layer(lp, x, positions, cfg: ModelConfig, sig, *, impl,
                 enc_out=None, moe_groups=1, moe_axes=(None, None)):
    """One layer on (B,S,D). Returns (x, aux, cache) — cache only the parts
    a later decode needs (collected by prefill)."""
    mix, ffn = sig
    cache = {}
    if mix == "attn":
        h = rms_norm(x, lp["mix"]["norm"], cfg.norm_eps)
        q, k, v = attn_mod.qkv_proj(lp["mix"], h, cfg, positions)
        o = attn_mod.chunked_attention(q, k, v, causal=True,
                                       window=cfg.attn.window) \
            if impl == "chunked" else attn_mod.direct_attention(
                q, k, v, causal=True, window=cfg.attn.window)
        B, S, H, hd = o.shape
        x = x + o.reshape(B, S, H * hd) @ lp["mix"]["wo"]
        cache["kv"] = (k, v)
    elif mix == "mla":
        x = mla_mod.mla_layer(lp["mix"], x, positions, cfg, impl=impl)
        h = rms_norm(x, lp["mix"]["norm"], cfg.norm_eps)  # for cache only
        # NOTE: cache must reflect the *input* latents; recompute from pre-x.
        cache["mla"] = None   # filled by the dedicated prefill path below
    elif mix == "mamba":
        h = rms_norm(x, lp["mix"]["norm"], cfg.norm_eps)
        y, (cs, ss) = mamba_mod.mamba_mix(lp["mix"], h, cfg, impl=impl)
        x = x + y
        cache["mamba"] = (cs, ss)
    elif mix == "rwkv6":
        h = rms_norm(x, lp["mix"]["norm"], cfg.norm_eps)
        y, (lx, st) = rwkv_mod.rwkv6_mix(lp["mix"], h, cfg, impl=impl)
        x = x + y
        cache["rwkv"] = (lx, st)
    else:
        raise ValueError(mix)

    if cfg.enc_dec and enc_out is not None:
        kc, vc = attn_mod.encode_cross_kv(lp["cross"], enc_out, cfg)
        x = attn_mod.cross_attn_layer(lp["cross"], x, (kc, vc), cfg)
        cache["cross"] = (kc, vc)

    if ffn == "dense":
        from repro.models.layers import dense_ffn
        x = dense_ffn(lp["ffn"], x, cfg.norm_eps)
        aux = jnp.zeros((), jnp.float32)
    else:
        x, aux = moe_mod.moe_ffn(lp["ffn"], x, cfg, num_groups=moe_groups,
                                 ep_axis=moe_axes[0], dp_axis=moe_axes[1])
    return x, aux, cache


def _mla_layer_with_cache(lp, x, positions, cfg):
    """Expanded MLA for prefill that also returns the latent cache content."""
    h = rms_norm(x, lp["norm"], cfg.norm_eps)
    c_kv, k_rope = mla_mod.latent_kv(lp, h, cfg, positions)
    x = mla_mod.mla_layer(lp, x, positions, cfg, impl="chunked")
    return x, (c_kv, k_rope[:, :, 0, :])


def encode(params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """Whisper encoder: frames (B, enc_len, D) → enc_out (B, enc_len, D)."""
    from repro.models.frontends import audio_frames_to_embeddings
    x = audio_frames_to_embeddings(params, frames)
    x = x + params["enc_pos"][None, : x.shape[1]]
    for lp in params["encoder"]:
        h = rms_norm(x, lp["mix"]["norm"], cfg.norm_eps)
        q, k, v = attn_mod.qkv_proj(lp["mix"], h, cfg, None)
        o = attn_mod.chunked_attention(q, k, v, causal=False)
        B, S, H, hd = o.shape
        x = x + o.reshape(B, S, H * hd) @ lp["mix"]["wo"]
        from repro.models.layers import dense_ffn
        x = dense_ffn(lp["ffn"], x, cfg.norm_eps)
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def embed_inputs(params, cfg: ModelConfig, tokens: jax.Array,
                 patches: jax.Array | None = None, *, mesh=None,
                 act_spec=None) -> jax.Array:
    """tokens (B,S_text) [+ patches (B,P,D)] → x (B,S,D)."""
    if mesh is not None and "model" in mesh.axis_names:
        from repro.distributed.collectives import vocab_parallel_embed
        dp = act_spec[0] if act_spec is not None else None
        x = vocab_parallel_embed(tokens, params["embed"], mesh=mesh,
                                 dp_spec=dp)
    else:
        x = embed(tokens, params["embed"])
    if cfg.frontend == "vision" and patches is not None:
        from repro.models.frontends import vision_patches_to_embeddings
        vis = vision_patches_to_embeddings(params, patches)
        x = jnp.concatenate([vis.astype(x.dtype), x], axis=1)
    if cfg.enc_dec:
        pos_idx = jnp.minimum(jnp.arange(x.shape[1]), 4095)
        x = x + params["dec_pos"][pos_idx][None]
    return x


def _constrain(x, act_spec):
    """Pin activation sharding (batch over data axes, D replicated across
    TP) — without this GSPMD is free to pick feature-sharded activations
    and re-reduce them at every matmul (observed: 16× redundant compute)."""
    if act_spec is None:
        return x
    spec = act_spec if x.ndim == 3 else jax.sharding.PartitionSpec(
        *act_spec[:x.ndim - 1], None)
    return jax.lax.with_sharding_constraint(x, spec)


def forward_hidden(params, cfg: ModelConfig, x: jax.Array, *,
                   impl: str = "chunked", enc_out=None, remat: bool = True,
                   moe_groups: int = 1, remat_policy=None, act_spec=None):
    """x: (B,S,D) embedded inputs → (hidden (B,S,D), aux_loss)."""
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    prefix, period = cfg.segmentation()
    aux = jnp.zeros((), jnp.float32)
    x = _constrain(x, act_spec)
    moe_axes = (("model", act_spec[0]) if act_spec is not None
                else (None, None))

    for i, lp in enumerate(params["prefix"]):
        x, a, _ = _apply_layer(lp, x, positions, cfg, cfg.layer_sig(i),
                               impl=impl, enc_out=enc_out,
                               moe_groups=moe_groups, moe_axes=moe_axes)
        x = _constrain(x, act_spec)
        aux = aux + a

    if period and params["body"]:
        sigs = [cfg.layer_sig(prefix + j) for j in range(period)]

        def blk(carry, xs):
            x, aux = carry
            for j in range(period):
                x, a, _ = _apply_layer(xs[j], x, positions, cfg, sigs[j],
                                       impl=impl, enc_out=enc_out,
                                       moe_groups=moe_groups,
                                       moe_axes=moe_axes)
                x = _constrain(x, act_spec)
                aux = aux + a
            return (x, aux), None

        if remat:
            blk = jax.checkpoint(blk, policy=remat_policy,
                                 prevent_cse=False)
        (x, aux), _ = jax.lax.scan(blk, (x, aux), params["body"])
    return x, aux


def chunked_loss(params, cfg: ModelConfig, hidden: jax.Array,
                 labels: jax.Array, chunk: int = 512) -> jax.Array:
    """Cross-entropy without materialising (B,S,V) logits at once."""
    B, S, D = hidden.shape
    h = rms_norm(hidden, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    while S % chunk:
        chunk //= 2
    n = S // chunk
    hc = h.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def step(acc, inp):
        # checkpointed: the backward recomputes the (B,chunk,V) logits per
        # chunk instead of scan stacking all of them (≈3.4 GB/chip saved)
        hk, lk = inp
        logits = unembed(hk, table)                  # (B,chunk,V) f32
        mask = lk != -100
        lab = jnp.where(mask, lk, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        nll, cnt = acc
        return (nll + ((logz - gold) * mask).sum(), cnt + mask.sum()), None

    (nll, cnt), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        (hc, lc))
    return nll / jnp.maximum(cnt, 1)


def loss_fn(params, cfg: ModelConfig, batch: dict, *, impl: str = "chunked",
            moe_groups: int = 1, remat_policy=None, act_spec=None,
            mesh=None) -> jax.Array:
    """batch: tokens (B,S), labels (B,S) [, patches (B,P,D), frames]."""
    enc_out = None
    if cfg.enc_dec:
        enc_out = encode(params, cfg, batch["frames"])
    x = embed_inputs(params, cfg, batch["tokens"], batch.get("patches"),
                     mesh=mesh, act_spec=act_spec)
    hidden, aux = forward_hidden(params, cfg, x, impl=impl, enc_out=enc_out,
                                 moe_groups=moe_groups,
                                 remat_policy=remat_policy,
                                 act_spec=act_spec)
    labels = batch["labels"]
    if cfg.frontend == "vision" and "patches" in batch:
        # prefix positions carry no LM loss
        P = batch["patches"].shape[1]
        pad = jnp.full((labels.shape[0], P), -100, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    return chunked_loss(params, cfg, hidden, labels) + aux


# ============================================================= decode state
def attn_layer_ids(cfg: ModelConfig) -> list[int]:
    return [i for i in range(cfg.n_layers) if cfg.mixers[i] == "attn"]


def mamba_layer_ids(cfg: ModelConfig) -> list[int]:
    return [i for i in range(cfg.n_layers) if cfg.mixers[i] == "mamba"]


def cache_spec(cfg: ModelConfig, batch: int, max_len: int,
               num_blocks: int | None = None,
               dtype=jnp.bfloat16, round_to: int = 1) -> dict:
    """Shapes/dtypes of the decode-state pytree (used for ShapeDtypeStruct
    dry-runs and real allocation alike).  num_blocks defaults to exactly
    enough blocks for batch×max_len tokens; ``round_to`` rounds the pool up
    so it divides evenly across (batch × sequence) shards."""
    bs = BLOCK_SIZE
    M = (max_len + bs - 1) // bs
    N = num_blocks if num_blocks is not None else batch * M
    N = ((N + round_to - 1) // round_to) * round_to
    spec: dict[str, Any] = {
        "tables": ((batch, M), jnp.int32),
        "lengths": ((batch,), jnp.int32),
    }
    n_attn = len(attn_layer_ids(cfg))
    n_mamba = len(mamba_layer_ids(cfg))
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    if cfg.mixers[0] == "mla" or "mla" in cfg.mixers:
        m = cfg.mla
        L = cfg.n_layers
        spec["mla_c"] = ((L, N, bs, m.kv_lora_rank), dtype)
        spec["mla_rope"] = ((L, N, bs, m.rope_head_dim), dtype)
    if n_attn:
        # fused head-interleaved K/V pool: K on even, V on odd head
        # indices — one block, one DMA (see kernels/paged_attention)
        spec["kv"] = ((n_attn, N, bs, 2 * KV, hd), dtype)
    if n_mamba:
        mm = cfg.mamba
        spec["conv"] = ((n_mamba, batch, mm.d_conv - 1, cfg.d_inner), dtype)
        spec["ssm"] = ((n_mamba, batch, cfg.d_inner, mm.d_state), jnp.float32)
    if "rwkv6" in cfg.mixers:
        L = cfg.n_layers
        nH = cfg.d_model // rwkv_mod.HEAD_SIZE
        spec["rwkv_x"] = ((L, batch, cfg.d_model), dtype)
        spec["rwkv_s"] = ((L, batch, nH, rwkv_mod.HEAD_SIZE,
                           rwkv_mod.HEAD_SIZE), jnp.float32)
    if cfg.enc_dec:
        L = cfg.n_layers
        spec["cross_k"] = ((L, batch, cfg.enc_len, KV, hd), dtype)
        spec["cross_v"] = ((L, batch, cfg.enc_len, KV, hd), dtype)
    return spec


def sp_identity_tables(batch: int, M: int, N: int, batch_shards: int = 1,
                       seq_shards: int = 1):
    """Global block-table layout consistent with an (batch × seq)-sharded
    pool: data shard ``di`` owns pool partitions ``di*seq + s`` (each
    ``Nl = N/(batch_shards*seq_shards)`` rows); block column ``m`` of local
    sequence ``bl`` lives on seq shard ``m // M_loc`` at local row
    ``bl*M_loc + m%M_loc``.  With (1,1) this is the identity ``b*M + m``."""
    import numpy as np
    Bl = batch // batch_shards
    M_loc = (M + seq_shards - 1) // seq_shards
    Nl = N // (batch_shards * seq_shards)
    assert Bl * M_loc <= Nl, (
        f"pool too small: need {Bl}x{M_loc} rows per shard, have {Nl}")
    b = np.arange(batch)[:, None]
    m = np.arange(M)[None, :]
    di, bl = b // Bl, b % Bl
    s, ml = m // M_loc, m % M_loc
    g = (di * seq_shards + s) * Nl + bl * M_loc + ml
    return jnp.asarray(g, jnp.int32)


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      num_blocks: int | None = None, dtype=jnp.bfloat16,
                      tables: jax.Array | None = None,
                      lengths: jax.Array | None = None,
                      batch_shards: int = 1, seq_shards: int = 1) -> dict:
    spec = cache_spec(cfg, batch, max_len, num_blocks, dtype,
                      round_to=batch_shards * seq_shards)
    st = {k: jnp.zeros(sh, dt) for k, (sh, dt) in spec.items()}
    if tables is not None:
        st["tables"] = tables
    else:
        (B_, M), _ = spec["tables"]
        N = spec["kv"][0][1] if "kv" in spec else (
            spec["mla_c"][0][1] if "mla_c" in spec else batch * M)
        st["tables"] = sp_identity_tables(batch, M, N, batch_shards,
                                          seq_shards)
    st["lengths"] = (lengths if lengths is not None
                     else jnp.zeros((batch,), jnp.int32))
    return st


# ================================================================ decode step
def _paged_attn(q, kv_pool, tables, lengths, *, page_impl, window,
                mesh=None, batch_axes=(), seq_axes=()):
    """Dispatch one decode-attention step over either table layout.

    ``kv_pool`` is the fused head-interleaved ``(N, bs, KV*2, hd)`` pool;
    ``tables`` is the monolithic ``(B, M)`` table or the device-native
    ``(W, Bs, M)`` shard stack.  The Pallas kernel consumes both
    directly (shard-native page walk over one-DMA fused blocks, with the
    autotuned multi-depth pipeline); the jnp reference and the
    sequence-parallel collectives see the split K/V *views* of the fused
    pool and the monolithic table through traced slices/transposes
    (never a host-side rebuild).
    """
    B = q.shape[0]
    if page_impl in ("pallas", "pallas_interpret"):
        from repro.kernels.paged_attention import autotune as pa_autotune
        from repro.kernels.paged_attention import ops as pa_ops
        _, bs, KV2, hd = kv_pool.shape
        tuned = pa_autotune.get_tuning(KV2 // 2, hd, bs)
        return pa_ops.paged_attention(
            q, kv_pool, tables, lengths, window=window,
            buffer_depth=tuned.buffer_depth,
            interpret=(page_impl == "pallas_interpret"))
    k_pool, v_pool = attn_mod.split_fused_kv(kv_pool)
    if page_impl in ("sp", "sp_opt"):
        from repro.distributed.collectives import paged_decode_attention_sp
        return paged_decode_attention_sp(
            q, k_pool, v_pool,
            attn_mod.assemble_shard_tables(tables)[:B], lengths, mesh=mesh,
            batch_axes=batch_axes, seq_axes=seq_axes, window=window,
            table_cols_sharded=(page_impl == "sp_opt"))
    return attn_mod.paged_decode_attention_ref(
        q, k_pool, v_pool, attn_mod.assemble_shard_tables(tables)[:B],
        lengths, window=window)


def _write_token_kv(pool, tables, lengths, new, bs):
    """Scatter one token's cache row into the paged pool.

    pool: (N, bs, ...) ; new: (B, ...) ; position = lengths (0-based index
    of the incoming token).  Non-resident (<0) table entries drop the write
    (mapped out of bounds — negative indices would *wrap*, not drop).
    """
    B = new.shape[0]
    blk_idx = lengths // bs                          # (B,)
    off = lengths % bs
    phys = attn_mod.lookup_slot_blocks(
        tables, jnp.arange(B), jnp.minimum(blk_idx, tables.shape[-1] - 1))
    phys = jnp.where(phys >= 0, phys, pool.shape[0])
    return pool.at[phys, off].set(new.astype(pool.dtype), mode="drop")


def _write_token_kv_stacked(pool, layer, tables, lengths, new, bs):
    """Per-layer-slice scatter into the stacked (L, N, bs, …) pool.

    The scatter runs on the (N, bs, …) layer slice, not the full stack:
    XLA:CPU lowers bf16 scatter via an f32 round-trip of the *operand*, so
    a full-stack scatter would materialise two pool-sized f32 temps per
    write (60× per decode step).  The slice is re-inserted with an in-place
    dynamic-update-slice.  (On TPU both forms scatter in place.)"""
    sl = _write_token_kv(
        jax.lax.index_in_dim(pool, layer, keepdims=False),
        tables, lengths, new, bs)
    return pool.at[layer].set(sl)


def decode_step(params, cfg: ModelConfig, state: dict, tokens: jax.Array, *,
                page_impl: str = "ref", mesh=None, batch_axes=(),
                seq_axes=(), moe_groups: int = 1):
    """One decode step: tokens (B,) int32 → (logits (B,V) f32, new state).

    ``state['lengths']`` counts tokens already in the cache; the incoming
    token is written at position ``lengths`` and attends to ``lengths+1``
    tokens (itself included).  Unrolled over layers.
    """
    B = tokens.shape[0]
    bs = BLOCK_SIZE
    st = dict(state)
    pos = st["lengths"]                              # (B,) position of token
    if mesh is not None and "model" in mesh.axis_names:
        from repro.distributed.collectives import vocab_parallel_embed
        ba = tuple(batch_axes)
        bspec = ba if len(ba) != 1 else (ba[0] if ba else None)
        act_spec = jax.sharding.PartitionSpec(bspec, None)
        x = vocab_parallel_embed(tokens, params["embed"], mesh=mesh,
                                 dp_spec=bspec)
    else:
        act_spec = None
        x = embed(tokens, params["embed"])           # (B, D)
    if cfg.enc_dec:
        x = x + params["dec_pos"][jnp.minimum(pos, 4095)]
    positions = pos[:, None]

    prefix, period = cfg.segmentation()
    aidx = midx = 0          # per-kind pool cursors
    attn_ids = attn_layer_ids(cfg)
    mamba_ids = mamba_layer_ids(cfg)

    def layer_params(i):
        if i < prefix:
            return params["prefix"][i]
        j = (i - prefix) % period
        b = (i - prefix) // period
        return jax.tree.map(lambda t: t[b], params["body"][j])

    for i in range(cfg.n_layers):
        lp = layer_params(i)
        mix, ffn = cfg.layer_sig(i)
        if mix == "attn":
            a = attn_ids.index(i)
            h = rms_norm(x[:, None], lp["mix"]["norm"], cfg.norm_eps)
            q, k, v = attn_mod.qkv_proj(lp["mix"], h, cfg, positions)
            st["kv"] = _write_token_kv_stacked(
                st["kv"], a, st["tables"], pos,
                attn_mod.fuse_kv(k[:, 0], v[:, 0]), bs)
            o = _paged_attn(q[:, 0], st["kv"][a], st["tables"],
                            pos + 1, page_impl=page_impl,
                            window=cfg.attn.window, mesh=mesh,
                            batch_axes=batch_axes, seq_axes=seq_axes)
            x = x + o.reshape(B, -1) @ lp["mix"]["wo"]
        elif mix == "mla":
            h = rms_norm(x[:, None], lp["mix"]["norm"], cfg.norm_eps)
            c_kv, k_rope = mla_mod.latent_kv(lp["mix"], h, cfg, positions)
            st["mla_c"] = _write_token_kv_stacked(
                st["mla_c"], i, st["tables"], pos, c_kv[:, 0], bs)
            st["mla_rope"] = _write_token_kv_stacked(
                st["mla_rope"], i, st["tables"], pos, k_rope[:, 0, 0], bs)
            x = _mla_paged_decode(lp["mix"], x, pos, st, i, cfg,
                                  page_impl=page_impl, mesh=mesh,
                                  batch_axes=batch_axes, seq_axes=seq_axes)
        elif mix == "mamba":
            m = mamba_ids.index(i)
            y, (cs, ss) = mamba_mod.mamba_decode_step(
                lp["mix"], x, cfg, st["conv"][m], st["ssm"][m])
            x = y
            st["conv"] = st["conv"].at[m].set(cs)
            st["ssm"] = st["ssm"].at[m].set(ss)
        elif mix == "rwkv6":
            y, (lx, s_new) = rwkv_mod.rwkv6_decode_step(
                lp["mix"], x, cfg, st["rwkv_x"][i], st["rwkv_s"][i])
            x = y
            st["rwkv_x"] = st["rwkv_x"].at[i].set(lx.astype(st["rwkv_x"].dtype))
            st["rwkv_s"] = st["rwkv_s"].at[i].set(s_new)

        if cfg.enc_dec:
            x = attn_mod.cross_attn_layer(
                lp["cross"], x[:, None],
                (st["cross_k"][i], st["cross_v"][i]), cfg)[:, 0]

        if ffn == "dense":
            from repro.models.layers import dense_ffn
            x = dense_ffn(lp["ffn"], x[:, None], cfg.norm_eps)[:, 0]
        else:
            out, _ = moe_mod.moe_ffn(
                lp["ffn"], x[:, None], cfg, num_groups=moe_groups,
                ep_axis="model" if act_spec is not None else None)
            x = out[:, 0]
        x = _constrain(x, act_spec)

    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed(h[:, None], table)[:, 0]
    st["lengths"] = pos + 1
    return logits, st


def _mla_paged_decode(lp, x, positions, st, layer, cfg, *, page_impl, mesh,
                      batch_axes, seq_axes):
    if page_impl in ("pallas", "pallas_interpret"):
        # shard-native: the MLA kernel walks the (W, Bs, M) stack through
        # the same _table_index arithmetic as paged_attention — no traced
        # transpose is materialized on this path
        from repro.kernels.mla_attention import ops as mla_ops
        return mla_ops.mla_paged_decode(
            lp, x, positions, st["mla_c"][layer], st["mla_rope"][layer],
            st["tables"], st["lengths"] + 1, cfg,
            interpret=(page_impl == "pallas_interpret"))
    # the jnp reference and sp collectives view the stack monolithically
    # through a traced transpose (never a host-side rebuild)
    tables = attn_mod.assemble_shard_tables(st["tables"])[:x.shape[0]]
    if page_impl in ("sp", "sp_opt"):
        from repro.distributed.collectives import mla_decode_sp
        return mla_decode_sp(lp, x, positions, st["mla_c"][layer],
                             st["mla_rope"][layer], tables,
                             st["lengths"] + 1, cfg, mesh=mesh,
                             batch_axes=batch_axes, seq_axes=seq_axes,
                             table_cols_sharded=(page_impl == "sp_opt"))
    return mla_mod.mla_decode_ref(lp, x, positions, st["mla_c"][layer],
                                  st["mla_rope"][layer], tables,
                                  st["lengths"] + 1, cfg)


# ================================================================== prefill
def prefill(params, cfg: ModelConfig, tokens: jax.Array, state: dict, *,
            impl: str = "chunked", enc_frames=None, patches=None,
            moe_groups: int = 1, remat: bool = True, mesh=None,
            batch_axes=(), seq_axes=("model",)):
    """Process a full prompt, write every cache, return (last_logits, state).

    tokens: (B, S).  The caches land exactly where decode_step expects them
    (token t of sequence b → pool[tables[b, t//bs], t%bs]).
    """
    B, S = tokens.shape
    bs = BLOCK_SIZE
    st = dict(state)
    if mesh is not None and "model" in mesh.axis_names:
        ba = tuple(batch_axes)
        bspec = ba if len(ba) != 1 else (ba[0] if ba else None)
        act_spec = jax.sharding.PartitionSpec(bspec, None, None)
    else:
        act_spec = None
    enc_out = encode(params, cfg, enc_frames) if cfg.enc_dec else None
    x = embed_inputs(params, cfg, tokens, patches, mesh=mesh,
                     act_spec=act_spec)
    S_tot = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S_tot)[None], (B, S_tot))
    prefix, period = cfg.segmentation()
    attn_ids = attn_layer_ids(cfg)
    mamba_ids = mamba_layer_ids(cfg)

    def run_layer(lp, x, i):
        """Returns (x, cache-dict for this layer)."""
        mix, ffn = cfg.layer_sig(i)
        cache = {}
        if mix == "attn":
            h = rms_norm(x, lp["mix"]["norm"], cfg.norm_eps)
            q, k, v = attn_mod.qkv_proj(lp["mix"], h, cfg, positions)
            o = attn_mod.chunked_attention(q, k, v, causal=True,
                                           window=cfg.attn.window)
            B_, S_, H, hd = o.shape
            x = x + o.reshape(B_, S_, H * hd) @ lp["mix"]["wo"]
            cache["kv"] = (k, v)
        elif mix == "mla":
            x, (c_kv, k_rope) = _mla_layer_with_cache(lp["mix"], x,
                                                      positions, cfg)
            cache["mla"] = (c_kv, k_rope)
        elif mix == "mamba":
            h = rms_norm(x, lp["mix"]["norm"], cfg.norm_eps)
            y, (cs, ss) = mamba_mod.mamba_mix(lp["mix"], h, cfg, impl=impl)
            x = x + y
            cache["mamba"] = (cs, ss)
        elif mix == "rwkv6":
            h = rms_norm(x, lp["mix"]["norm"], cfg.norm_eps)
            y, (lx, s_new) = rwkv_mod.rwkv6_mix(lp["mix"], h, cfg, impl=impl)
            x = x + y
            cache["rwkv"] = (lx, s_new)
        if cfg.enc_dec:
            kc, vc = attn_mod.encode_cross_kv(lp["cross"], enc_out, cfg)
            x = attn_mod.cross_attn_layer(lp["cross"], x, (kc, vc), cfg)
            cache["cross"] = (kc, vc)
        if ffn == "dense":
            from repro.models.layers import dense_ffn
            x = dense_ffn(lp["ffn"], x, cfg.norm_eps)
        else:
            x, _ = moe_mod.moe_ffn(
                lp["ffn"], x, cfg, num_groups=moe_groups,
                ep_axis="model" if act_spec is not None else None,
                dp_axis=act_spec[0] if act_spec is not None else None)
        return _constrain(x, act_spec), cache

    # ---- streaming cache writes (inside the layer scan) --------------------
    # Stacking every layer's (B, S, KV, hd) cache out of the scan and
    # scattering afterwards would materialise the entire KV cache a second
    # time (tens of GB/chip for prefill_32k); instead each layer scatters
    # its rows into the pools as it runs, and the pools ride the scan carry.
    tables_const = attn_mod.assemble_shard_tables(st["tables"])[:B]

    def scatter_seq(pool, seq):
        """seq: (B, S_tot, ...) → paged pool (N, bs, ...); <0 entries drop."""
        pad = (-S_tot) % bs
        if pad:
            seq = jnp.pad(seq, ((0, 0), (0, pad)) + ((0, 0),) * (seq.ndim - 2))
        M_used = seq.shape[1] // bs
        seq = seq.reshape((B * M_used, bs) + seq.shape[2:])
        tab = tables_const[:, :M_used].reshape(-1)
        if mesh is not None and "model" in mesh.axis_names:
            from repro.distributed.collectives import scatter_seq_sp
            return scatter_seq_sp(pool, seq, tab, mesh=mesh,
                                  batch_axes=batch_axes,
                                  seq_axes=seq_axes)
        neg = jnp.where(tab >= 0, tab, pool.shape[0])
        return pool.at[neg].set(seq.astype(pool.dtype), mode="drop")

    def _dyn_write(pool, idx, value):
        """pool[idx] = value with a (possibly traced) leading index."""
        return jax.lax.dynamic_update_index_in_dim(
            pool, value, idx, 0)

    def _dyn_scatter(pool, idx, seq):
        cur = jax.lax.dynamic_index_in_dim(pool, idx, 0, keepdims=False)
        return jax.lax.dynamic_update_index_in_dim(
            pool, scatter_seq(cur, seq), idx, 0)

    def write_caches(stt, i_dyn, a_dyn, m_dyn, c):
        if "kv" in c:
            k, v = c["kv"]
            stt["kv"] = _dyn_scatter(stt["kv"], a_dyn,
                                     attn_mod.fuse_kv(k, v))
        if "mla" in c and c["mla"] is not None:
            ckv, krope = c["mla"]
            stt["mla_c"] = _dyn_scatter(stt["mla_c"], i_dyn, ckv)
            stt["mla_rope"] = _dyn_scatter(stt["mla_rope"], i_dyn, krope)
        if "mamba" in c:
            cs, ss = c["mamba"]
            stt["conv"] = _dyn_write(stt["conv"], m_dyn,
                                     cs.astype(stt["conv"].dtype))
            stt["ssm"] = _dyn_write(stt["ssm"], m_dyn, ss)
        if "rwkv" in c:
            lx, s_new = c["rwkv"]
            stt["rwkv_x"] = _dyn_write(stt["rwkv_x"], i_dyn,
                                       lx.astype(stt["rwkv_x"].dtype))
            stt["rwkv_s"] = _dyn_write(stt["rwkv_s"], i_dyn, s_new)
        if "cross" in c:
            kc, vc = c["cross"]
            stt["cross_k"] = _dyn_write(stt["cross_k"], i_dyn,
                                        kc.astype(stt["cross_k"].dtype))
            stt["cross_v"] = _dyn_write(stt["cross_v"], i_dyn,
                                        vc.astype(stt["cross_v"].dtype))
        return stt

    pool_keys = [k for k in st if k not in ("tables", "lengths")]
    pools = {k: st[k] for k in pool_keys}

    for i in range(prefix):
        x, c = run_layer(params["prefix"][i], x, i)
        a = attn_ids.index(i) if cfg.mixers[i] == "attn" else 0
        m = mamba_ids.index(i) if cfg.mixers[i] == "mamba" else 0
        pools = write_caches(pools, i, a, m, c)

    if period and params["body"]:
        sigs = [cfg.layer_sig(prefix + j) for j in range(period)]
        attn_js = [j for j in range(period) if sigs[j][0] == "attn"]
        mamba_js = [j for j in range(period) if sigs[j][0] == "mamba"]
        attn_base = sum(1 for i in range(prefix) if cfg.mixers[i] == "attn")
        mamba_base = sum(1 for i in range(prefix)
                         if cfg.mixers[i] == "mamba")
        n_blocks = (cfg.n_layers - prefix) // period

        def blk(carry, inp):
            x, pl = carry
            lps, b = inp
            for j in range(period):
                x, c = run_layer(lps[j], x, prefix + j)   # sig via static j
                i_dyn = prefix + b * period + j
                a_dyn = (attn_base + b * len(attn_js)
                         + (attn_js.index(j) if j in attn_js else 0))
                m_dyn = (mamba_base + b * len(mamba_js)
                         + (mamba_js.index(j) if j in mamba_js else 0))
                pl = write_caches(pl, i_dyn, a_dyn, m_dyn, c)
            return (x, pl), None

        (x, pools), _ = jax.lax.scan(
            blk, (x, pools), (params["body"], jnp.arange(n_blocks)))

    st.update(pools)
    h = rms_norm(x[:, -1], params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed(h[:, None], table)[:, 0]
    st["lengths"] = jnp.full((B,), S_tot, jnp.int32)
    return logits, st


def prefill_chunk(params, cfg: ModelConfig, tokens: jax.Array, state: dict,
                  start, *, moe_groups: int = 1) -> dict:
    """One fixed-shape prefill chunk: tokens (B, C) at positions
    ``start .. start+C-1`` (C a BLOCK_SIZE multiple; ``start``
    block-aligned and *traced*, so the whole function compiles once per
    (B, C) shape — no per-prompt-length retrace).

    Attention-only decoder models (the engine gates on that): each layer
    scatters the chunk's K/V rows into the paged pools at the chunk's
    table columns, then attends the *full* gathered window with
    ``q_offset=start``.  Keys past the causal frontier are garbage pool
    rows, but the online softmax masks them to ``NEG_INF`` and
    ``exp(NEG_INF - m)`` underflows to exactly ``0.0`` — so for every real
    query row the result matches monolithic :func:`prefill` bit-for-bit
    whenever the pool dtype round-trips K/V exactly (float32 caches; the
    engine's bit-identity tests pin it).  Chunk-pad rows past the prompt
    write deterministic garbage that decode rewrites position-by-position
    before ever attending it.  Returns the new state dict; logits are not
    computed — the engine's first decode step rewrites position S−1 and
    produces them, identically in both prefill paths.
    """
    if any(m != "attn" for m in cfg.mixers) or cfg.enc_dec:
        raise NotImplementedError(
            "prefill_chunk supports attention-only decoder models; "
            f"got mixers={cfg.mixers} enc_dec={cfg.enc_dec}")
    B, C = tokens.shape
    bs = BLOCK_SIZE
    if C % bs:
        raise ValueError(f"chunk length {C} must be a multiple of "
                         f"BLOCK_SIZE={bs}")
    st = dict(state)
    x = embed_inputs(params, cfg, tokens)
    positions = jnp.broadcast_to(start + jnp.arange(C)[None], (B, C))
    prefix, period = cfg.segmentation()
    tables_const = attn_mod.assemble_shard_tables(st["tables"])[:B]
    M = tables_const.shape[1]
    Cb = C // bs
    # the chunk's table columns: a traced window of Cb columns starting at
    # start//bs; columns past the window width map to -1 (writes drop)
    cols = start // bs + jnp.arange(Cb)                       # (Cb,)
    chunk_tab = jnp.where(cols[None, :] < M,
                          jnp.take(tables_const,
                                   jnp.minimum(cols, M - 1), axis=1),
                          -1)                                 # (B, Cb)

    def scatter_chunk(pool, seq):
        """seq (B, C, ...) → the chunk's pool rows; <0 entries drop."""
        seq = seq.reshape((B * Cb, bs) + seq.shape[2:])
        tab = chunk_tab.reshape(-1)
        neg = jnp.where(tab >= 0, tab, pool.shape[0])
        return pool.at[neg].set(seq.astype(pool.dtype), mode="drop")

    def gather_window(pool):
        """Full-window keys (B, M*bs, ...) — unallocated (-1) columns
        gather arbitrary resident rows; they sit past the causal frontier
        and the attention mask zeroes them exactly."""
        rows = jnp.take(pool, jnp.maximum(tables_const, 0).reshape(-1),
                        axis=0)
        return rows.reshape((B, M * bs) + pool.shape[2:])

    def run_layer(lp, x, pools, a_dyn, sig):
        _, ffn = sig
        h = rms_norm(x, lp["mix"]["norm"], cfg.norm_eps)
        q, k, v = attn_mod.qkv_proj(lp["mix"], h, cfg, positions)
        kvp = jax.lax.dynamic_index_in_dim(pools["kv"], a_dyn, 0,
                                           keepdims=False)
        kvp = scatter_chunk(kvp, attn_mod.fuse_kv(k, v))
        pools = dict(pools)
        pools["kv"] = jax.lax.dynamic_update_index_in_dim(pools["kv"], kvp,
                                                          a_dyn, 0)
        kw, vw = attn_mod.split_fused_kv(gather_window(kvp))
        o = attn_mod.chunked_attention_fwd(
            q, kw, vw, causal=True,
            window=cfg.attn.window, q_offset=start)
        B_, C_, H, hd = o.shape
        x = x + o.reshape(B_, C_, H * hd) @ lp["mix"]["wo"]
        if ffn == "dense":
            from repro.models.layers import dense_ffn
            x = dense_ffn(lp["ffn"], x, cfg.norm_eps)
        else:
            x, _ = moe_mod.moe_ffn(lp["ffn"], x, cfg,
                                   num_groups=moe_groups)
        return x, pools

    pools = {k: st[k] for k in st if k not in ("tables", "lengths")}
    for i in range(prefix):
        x, pools = run_layer(params["prefix"][i], x, pools, i,
                             cfg.layer_sig(i))
    if period and params["body"]:
        sigs = [cfg.layer_sig(prefix + j) for j in range(period)]
        n_blocks = (cfg.n_layers - prefix) // period

        def blk(carry, inp):
            x, pl = carry
            lps, b = inp
            for j in range(period):
                # all-attn model: attn pool index of layer i is i itself
                x, pl = run_layer(lps[j], x, pl, prefix + b * period + j,
                                  sigs[j])
            return (x, pl), None

        (x, pools), _ = jax.lax.scan(
            blk, (x, pools), (params["body"], jnp.arange(n_blocks)))
    st.update(pools)
    return st


def ragged_step(params, cfg: ModelConfig, state: dict, tokens: jax.Array,
                token_row: jax.Array, token_pos: jax.Array,
                tile_row: jax.Array, tile_pos: jax.Array,
                kv_lens: jax.Array, last_index: jax.Array, *,
                page_impl: str = "ref",
                moe_groups: int = 1) -> tuple[jax.Array, dict]:
    """One *ragged* engine step: every chunked-prefill AND decode row of
    the scheduler batch packed into one fixed-shape token stream and one
    attention kernel call per layer.

    tokens/token_row/token_pos: (T,) packed incoming tokens, their batch
    slots (-1 = padding, writes drop) and global positions; tile_row/
    tile_pos: (T // QT,) per-query-tile descriptor for the kernel;
    kv_lens: (num_slots,) kv length of each slot *after* this step's
    writes land; last_index: (num_slots,) packed index of each slot's
    final real token (-1 = inactive slot — its logits row is garbage and
    its length is left untouched).  All shapes are static, so the whole
    mixed step — any blend of prefill chunks and single-token decodes —
    compiles exactly once.  Returns (logits (num_slots, V) gathered at
    each slot's last token, new state).

    Per layer the incoming fused K/V rows are scattered *before* the
    attention call (so a chunk attends its own tokens, matching
    :func:`prefill_chunk`), and the ragged fused kernel masks causality,
    length, window and holes per (query, key) element — decode rows are
    simply q_len-1 chunks, so the numerics match :func:`decode_step` and
    :func:`prefill_chunk` exactly.
    """
    if any(m != "attn" for m in cfg.mixers) or cfg.enc_dec:
        raise NotImplementedError(
            "ragged_step supports attention-only decoder models; "
            f"got mixers={cfg.mixers} enc_dec={cfg.enc_dec}")
    T = tokens.shape[0]
    bs = BLOCK_SIZE
    st = dict(state)
    M = st["tables"].shape[-1]
    x = embed(tokens, params["embed"])                   # (T, D)
    positions = token_pos[None]                          # (1, T)
    prefix, period = cfg.segmentation()

    # per-token scatter targets: padding rows, non-resident blocks and
    # out-of-window positions all map past the pool end (mode="drop")
    valid = token_row >= 0
    slot = jnp.maximum(token_row, 0)
    blk_idx = token_pos // bs
    off = token_pos % bs
    phys = attn_mod.lookup_slot_blocks(
        st["tables"], slot, jnp.minimum(blk_idx, M - 1))
    drop = valid & (phys >= 0) & (blk_idx < M)

    def ragged_attn(q, kvp):
        """q: (T, H, hd) over the fused (N, bs, KV*2, hd) layer pool."""
        if page_impl in ("pallas", "pallas_interpret"):
            from repro.kernels.paged_attention import ops as pa_ops
            return pa_ops.ragged_paged_attention(
                q, kvp, st["tables"], tile_row, tile_pos, kv_lens,
                window=cfg.attn.window,
                interpret=(page_impl == "pallas_interpret"))
        from repro.kernels.paged_attention.ref import ragged_fused_ref
        return ragged_fused_ref(q, kvp, st["tables"], token_row,
                                token_pos, kv_lens,
                                window=cfg.attn.window)

    def run_layer(lp, x, pools, a_dyn, sig):
        _, ffn = sig
        h = rms_norm(x[None], lp["mix"]["norm"], cfg.norm_eps)
        q, k, v = attn_mod.qkv_proj(lp["mix"], h, cfg, positions)
        rows = attn_mod.fuse_kv(k[0], v[0])              # (T, KV*2, hd)
        kvp = jax.lax.dynamic_index_in_dim(pools["kv"], a_dyn, 0,
                                           keepdims=False)
        tgt = jnp.where(drop, jnp.maximum(phys, 0), kvp.shape[0])
        kvp = kvp.at[tgt, off].set(rows.astype(kvp.dtype), mode="drop")
        pools = dict(pools)
        pools["kv"] = jax.lax.dynamic_update_index_in_dim(
            pools["kv"], kvp, a_dyn, 0)
        o = ragged_attn(q[0], kvp)                       # (T, H, hd)
        x = x + o.reshape(T, -1) @ lp["mix"]["wo"]
        if ffn == "dense":
            from repro.models.layers import dense_ffn
            x = dense_ffn(lp["ffn"], x[None], cfg.norm_eps)[0]
        else:
            out, _ = moe_mod.moe_ffn(lp["ffn"], x[None], cfg,
                                     num_groups=moe_groups)
            x = out[0]
        return x, pools

    pools = {k: st[k] for k in st if k not in ("tables", "lengths")}
    for i in range(prefix):
        x, pools = run_layer(params["prefix"][i], x, pools, i,
                             cfg.layer_sig(i))
    if period and params["body"]:
        sigs = [cfg.layer_sig(prefix + j) for j in range(period)]
        n_blocks = (cfg.n_layers - prefix) // period

        def blk(carry, inp):
            x, pl = carry
            lps, b = inp
            for j in range(period):
                x, pl = run_layer(lps[j], x, pl, prefix + b * period + j,
                                  sigs[j])
            return (x, pl), None

        (x, pools), _ = jax.lax.scan(
            blk, (x, pools), (params["body"], jnp.arange(n_blocks)))
    st.update(pools)

    h = rms_norm(x, params["final_norm"], cfg.norm_eps)  # (T, D)
    h_last = h[jnp.maximum(last_index, 0)]               # (slots, D)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed(h_last[:, None], table)[:, 0]
    st["lengths"] = jnp.where(last_index >= 0,
                              kv_lens[:st["lengths"].shape[0]].astype(
                                  jnp.int32),
                              st["lengths"])
    return logits, st
