"""Shared building blocks: norms, rotary embeddings, FFNs, initializers."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def init_dense(key, d_in: int, d_out: int, dtype=jnp.bfloat16) -> jax.Array:
    scale = 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
            ).astype(dtype)


# ----------------------------------------------------------------- rotary ----
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10_000.0
               ) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------- FFNs ----
def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def init_swiglu(key, d_model: int, d_ff: int, dtype=jnp.bfloat16) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": init_dense(k1, d_model, d_ff, dtype),
            "w_up": init_dense(k2, d_model, d_ff, dtype),
            "w_down": init_dense(k3, d_ff, d_model, dtype)}


def dense_ffn(params: dict, x: jax.Array, eps: float) -> jax.Array:
    h = rms_norm(x, params["norm"], eps)
    return x + swiglu(h, params["w_gate"], params["w_up"], params["w_down"])


# ------------------------------------------------------------- embeddings ----
def init_embed(key, vocab: int, d_model: int, dtype=jnp.bfloat16) -> jax.Array:
    return (jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02
            ).astype(dtype)


def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed(x: jax.Array, table: jax.Array) -> jax.Array:
    """Logits in f32 (loss stability)."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      table.astype(jnp.float32))


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  ignore_id: int = -100) -> jax.Array:
    mask = (labels != ignore_id)
    labels = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)
